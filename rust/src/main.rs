//! `aic` — the Approximate Intermittent Computing coordinator CLI.
//!
//! Every figure of the paper is a named built-in scenario, and `sweep`
//! runs arbitrary campaign grids from a JSON scenario file (writing
//! markdown to stdout and CSV/JSON under `--out`). The remaining
//! subcommands inspect the energy traces, check the AOT artifacts
//! through PJRT, and run free-form single-device simulations.

use aic::coordinator::experiment::{
    self, AudioRunSpec, HarContext, HarRunSpec, ImgRunSpec, SupplyCache,
};
use aic::coordinator::scenario::{builtin, DeviceSpec, HarvesterSpec, Scenario, BUILTIN_NAMES};
use aic::coordinator::sink::{self, pct, TableData};
use aic::coordinator::store::Store;
use aic::coordinator::stream::{run_streaming, StreamOptions, DEFAULT_CHUNK};
use aic::energy::traces::{generate, TraceKind};
use aic::exec::engine::EngineKind;
use aic::exec::Policy;
use aic::util::cli::Args;
use aic::util::json;
use std::path::Path;

const USAGE: &str = "aic — approximate intermittent computing (paper reproduction)

USAGE: aic <command> [--out out] [--fast] [options]

COMMANDS:
  fig4            expected vs measured accuracy vs feature count
  fig5            emulation: accuracy + throughput per policy
  fig6            emulation: latency distribution (power cycles)
  fig7            real-world: coherence + throughput vs continuous
  fig8            real-world: coherence + throughput vs Chinchilla
  fig9            real-world: latency distribution
  fig12           corner output vs perforation rate
  fig13           corner equivalence per energy trace
  fig14           imaging throughput per energy trace
  fig15           imaging latency distribution per trace
  audio           anytime acoustic event detection on the five ambient
                  traces (the third workload's builtin grid)
  synth_solar     imaging on a generated diurnal-solar environment family
  synth_rf        audio on a generated duty-cycled RF environment family
  synth_multi     HAR on a generated multi-source (amalgamated) device
                  (10 environment seeds each; see energy/synth)
  adaptive_solar  adaptive learner vs static policies on the solar family
  adaptive_rf     adaptive learner vs static policies on the RF family
  adaptive_multi  adaptive learner vs static policies on the multi-source
                  family (Pareto projection: frontier + auto-selection)
  fleet [NAME]    simulated multi-device fleet with coordination-free
                  delta sync (default: fleet_solar; also a builtin name)
  fleet_solar     4-device fleet on the diurnal-solar family (latency
                  projection: detection propagation across the fleet)
  fleet_multi     6-device lossy fleet (20% drop, 3 s clock skew) on the
                  multi-source family (convergence projection)
  all             every figure in sequence
  sweep FILE      run a scenario file: any workload (har|img|audio) x
                  harvester x device x policy x seed grid (also:
                  --scenario FILE); see examples/scenarios/*.json.
                  Campaign grids stream cell by cell; with --store FILE
                  every finished cell is committed to an append-only
                  experiment store and a re-run resumes where a killed
                  one stopped, producing byte-identical outputs
  store ACTION    inspect an experiment store (--store FILE):
                  status — experiments + integrity counters
                  table  — rebuild a grid's cells table (--label L picks
                           the experiment when the file holds several)
                  export — dump to stdout: --format csv|json|sql
  traces          synthetic energy trace statistics (Fig. 11)
  artifacts-check load + execute every AOT artifact through PJRT
  simulate        one campaign: --policy greedy|smartNN|smart:BOUND|
                  adaptive[:ALPHA:EXPLORE]|chinchilla|alpaca|continuous
                  --supply rf|som|sim|sor|sir|kinetic|synth:SPEC.json
                  (--trace is an alias) --horizon secs
                  --workload har|img|audio (default: har on kinetic,
                  img on everything else)

OPTIONS:
  --out DIR       output directory for CSV/JSON (default: out)
  --fast          smaller campaigns (each scenario's own fast-mode scaling)
  --store FILE    sweep/store: the experiment store file (.aic)
  --label NAME    sweep: experiment label in the store (default: the
                  scenario's name); store table/export: experiment selector
  --chunk N       sweep: cells dispatched per streaming round (default 256)
  --format F      store export format: csv (default), json, or sql
  --seed N        base seed for figure scenarios and simulate (default 42;
                  sweep takes its seeds from the scenario file)
  --engine E      device integrator: analytic (default, event-driven) or
                  step (the fixed-step reference engine); threaded through
                  the scenario's device spec (AIC_ENGINE stays a read-only
                  fallback)
";

fn main() {
    let args = Args::from_env_with_flags(&["fast", "help"]);
    let out = args.get_or("out", "out").to_string();
    let fast = args.flag("fast");
    let seed = args.get_u64("seed", 42);
    // The integrator escape hatch: lands in every device spec of the
    // scenario instead of mutating the process environment (set_var is
    // racy with the fleet's worker threads).
    let engine = match args.get("engine") {
        None => None,
        Some(spelling) => match EngineKind::parse(spelling) {
            Some(kind) => Some(kind),
            None => {
                eprintln!("error: unknown engine '{spelling}' (expected analytic|step)\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        },
    };
    let cmd = args.command().unwrap_or("help").to_string();
    match cmd.as_str() {
        "all" => run_all(seed, fast, engine, &out),
        "sweep" => run_sweep(&args, fast, engine, &out),
        "store" => run_store(&args),
        "traces" => run_traces(&out, seed),
        "artifacts-check" => run_artifacts_check(args.get_or("artifacts", "artifacts")),
        "simulate" => run_simulate(&args, seed, engine),
        "fleet" => {
            // `aic fleet` runs a named fleet builtin (default fleet_solar);
            // the builtin names themselves also dispatch directly below.
            let name = args.positional_at(1).unwrap_or("fleet_solar");
            if !BUILTIN_NAMES.contains(&name) {
                eprintln!("error: unknown fleet scenario '{name}' (try fleet_solar|fleet_multi)\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
            run_figure(name, seed, fast, engine, &out, None)
        }
        name if BUILTIN_NAMES.contains(&name) => {
            run_figure(name, seed, fast, engine, &out, None)
        }
        _ => print!("{USAGE}"),
    }
}

fn emit(tables: &[TableData], out: &str) {
    let mut sinks = sink::standard(out);
    sink::emit_all(tables, &mut sinks).expect("write figure data");
}

/// Run one named figure scenario. `ctx` shares an already-trained HAR
/// context across figures (`aic all`).
fn run_figure(
    name: &str,
    seed: u64,
    fast: bool,
    engine: Option<EngineKind>,
    out: &str,
    ctx: Option<&HarContext>,
) {
    let mut sc = builtin(name, seed).expect("known figure scenario");
    if let Some(kind) = engine {
        sc = sc.with_engine(kind);
    }
    let run = sc.run_with(fast, ctx, None);
    emit(&run.tables(), out);
}

fn run_all(seed: u64, fast: bool, engine: Option<EngineKind>, out: &str) {
    // One HAR context for the whole sweep: the corpus, the trained OVR
    // SVM and the fitted class model are identical across figs. 4-9, so
    // train once and share read-only across every figure's fleet jobs.
    // fig4 always reports full-fidelity curves: in --fast runs it trains
    // its own full context while figs. 5-9 share the CI-sized one.
    if fast {
        run_figure("fig4", seed, false, engine, out, None);
        let ctx = builtin("fig5", seed).expect("fig5").resolve(true).har_context();
        for name in ["fig5", "fig6", "fig7", "fig8", "fig9"] {
            run_figure(name, seed, true, engine, out, Some(&ctx));
        }
    } else {
        let ctx = builtin("fig5", seed).expect("fig5").har_context();
        for name in ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9"] {
            run_figure(name, seed, false, engine, out, Some(&ctx));
        }
    }
    for name in ["fig12", "fig13", "fig14", "fig15"] {
        run_figure(name, seed, fast, engine, out, None);
    }
}

fn run_sweep(args: &Args, fast: bool, engine: Option<EngineKind>, out: &str) {
    if args.get("seed").is_some() {
        // Seeds are part of the grid: every cell's seed comes from the
        // scenario file, so a global --seed would be misleading.
        eprintln!("note: --seed is ignored by sweep (seeds come from the scenario file)");
    }
    let Some(path) = args.get("scenario").or_else(|| args.positional_at(1)) else {
        eprintln!("error: sweep needs a scenario file (aic sweep file.json)\n");
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) => {
            eprintln!("error: cannot read scenario '{path}': {e}");
            std::process::exit(2);
        }
    };
    let mut sc = match Scenario::parse(&text) {
        Ok(sc) => sc,
        Err(e) => {
            eprintln!("error: scenario '{path}': {e}");
            std::process::exit(2);
        }
    };
    if let Some(kind) = engine {
        sc = sc.with_engine(kind);
    }
    let mut store = match args.get("store") {
        None => None,
        Some(store_path) => match Store::open(Path::new(store_path)) {
            Ok(st) => Some(st),
            Err(e) => {
                eprintln!("error: cannot open store '{store_path}': {e}");
                std::process::exit(2);
            }
        },
    };
    let opts = StreamOptions {
        fast,
        workers: None,
        chunk: args.get_u64("chunk", DEFAULT_CHUNK as u64) as usize,
        label: args.get("label").unwrap_or(&sc.name).to_string(),
        // CI kill/resume harness: abort mid-campaign after N committed
        // cells, exactly like a power failure would.
        stop_after: std::env::var("AIC_STREAM_KILL_AFTER")
            .ok()
            .and_then(|s| s.parse::<u64>().ok()),
    };
    let cache = SupplyCache::from_env();
    let mut sinks = sink::standard(out);
    let report = run_streaming(&sc, &opts, None, &cache, store.as_mut(), &mut sinks)
        .expect("write sweep data");
    if report.partial {
        eprintln!(
            "sweep interrupted after {} fresh cells ({} reused); resume with the same --store",
            report.ran, report.reused
        );
        std::process::exit(137);
    }
    if report.reused > 0 {
        eprintln!("resumed: {} of {} cells from the store", report.reused, report.cells);
    }
}

fn run_store(args: &Args) {
    let action = args.positional_at(1).unwrap_or("status").to_string();
    let Some(path) = args.get("store").or_else(|| args.positional_at(2)) else {
        eprintln!("error: store needs a store file (aic store {action} --store runs.aic)\n");
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let mut store = match Store::open(Path::new(path)) {
        Ok(st) => st,
        Err(e) => {
            eprintln!("error: cannot open store '{path}': {e}");
            std::process::exit(2);
        }
    };
    let fail = |e: String| -> ! {
        eprintln!("error: {e}");
        std::process::exit(2);
    };
    match action.as_str() {
        "status" => {
            let tables = store.status_tables();
            let mut md = sink::markdown_stdout();
            sink::emit_all(&tables, &mut md).expect("write store status");
        }
        "table" => {
            let t = store.cells_table(args.get("label")).unwrap_or_else(|e| fail(e));
            let mut md = sink::markdown_stdout();
            sink::emit_all(&[t], &mut md).expect("write store table");
        }
        "export" => match args.get_or("format", "csv") {
            "csv" => {
                let t = store.cells_table(args.get("label")).unwrap_or_else(|e| fail(e));
                print!("{}", t.to_csv());
            }
            "json" => {
                let t = store.cells_table(args.get("label")).unwrap_or_else(|e| fail(e));
                println!("{}", json::to_string_pretty(&t.to_json()));
            }
            "sql" => {
                let dump = store.sql_dump().expect("read store records");
                print!("{dump}");
            }
            other => {
                eprintln!("error: unknown export format '{other}' (expected csv|json|sql)\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        },
        other => {
            eprintln!("error: unknown store action '{other}' (expected status|table|export)\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn run_traces(out: &str, seed: u64) {
    let mut t = TableData::new(
        "fig11_traces",
        "Fig. 11 — synthetic energy traces",
        &["trace", "mean power (uW)", "total energy (J/h)", "variability (cv)"],
    );
    for kind in TraceKind::ALL {
        let tr = generate(kind, 3600.0, 0.01, seed);
        t.push(vec![
            kind.name().to_string(),
            format!("{:.1}", tr.mean_power() * 1e6),
            format!("{:.3}", tr.total_energy()),
            format!("{:.2}", tr.variability()),
        ]);
    }
    emit(&[t], out);
}

fn run_artifacts_check(dir: &str) {
    use aic::runtime::{ArtifactRuntime, Tensor};
    let rt = match ArtifactRuntime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact load failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!("loaded {} artifacts on {} device(s)", rt.names().len(), rt.device_count());
    for name in rt.names() {
        let shapes = rt.input_shapes(&name);
        let inputs: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s.clone())).collect();
        match rt.execute(&name, &inputs) {
            Ok(out) => println!("  {name}: inputs {shapes:?} -> output {:?} OK", out.shape),
            Err(e) => {
                eprintln!("  {name}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("artifacts-check OK");
}

fn run_simulate(args: &Args, seed: u64, engine: Option<EngineKind>) {
    // Unknown names are an error, not a silent Greedy fallback.
    let policy: Policy = match args.get_or("policy", "greedy").parse() {
        Ok(policy) => policy,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    let horizon = args.get_f64("horizon", 3600.0);
    let supply =
        args.get("supply").or_else(|| args.get("trace")).unwrap_or("kinetic").to_string();
    // Like --policy: an unknown supply is an error, not a silent
    // fallback. Parsed once — every workload runs on any supply,
    // including generated synth environments (`synth:<spec.json>`).
    let harvester = if let Some(path) = supply.strip_prefix("synth:") {
        let text = match std::fs::read_to_string(path) {
            Ok(text) => text,
            Err(e) => {
                eprintln!("error: cannot read synth spec '{path}': {e}");
                std::process::exit(2);
            }
        };
        match aic::energy::synth::SynthSpec::parse(&text) {
            Ok(spec) => HarvesterSpec::Synth(spec),
            Err(e) => {
                eprintln!("error: synth spec '{path}': {e}");
                std::process::exit(2);
            }
        }
    } else {
        match HarvesterSpec::from_name(&supply.to_lowercase()) {
            Some(h) => h,
            None => {
                eprintln!(
                    "error: unknown supply '{supply}' \
                     (expected rf|som|sim|sor|sir|kinetic|synth:SPEC.json)\n"
                );
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        }
    };
    let device = DeviceSpec { engine, ..DeviceSpec::default() };
    let workload = args
        .get_or(
            "workload",
            if harvester == HarvesterSpec::Kinetic { "har" } else { "img" },
        )
        .to_string();
    match workload.as_str() {
        "audio" => {
            let spec = AudioRunSpec { horizon, stream_seed: seed, ..Default::default() };
            let c = experiment::run_audio_policy_on(&spec, harvester.clone(), policy, &device);
            println!(
                "AUDIO {} on {}: {} results, {} cycles, {} failures, acc {}, app {:.2} mJ, state {:.2} mJ",
                policy.name(),
                harvester.name(),
                c.emitted().count(),
                c.power_cycles,
                c.power_failures,
                pct(aic::coordinator::metrics::audio_accuracy(&c)),
                c.app_energy * 1e3,
                c.state_energy * 1e3,
            );
        }
        "har" => {
            let ctx = HarContext::build(seed ^ 0xC0FFEE);
            let spec = HarRunSpec { horizon, sample_period: 60.0, script_seed: seed };
            let c =
                experiment::run_har_policy_on(&ctx, &spec, harvester.clone(), policy, &device);
            println!(
                "HAR {} on {}: {} results, {} cycles, {} failures, acc {}, app {:.2} mJ, state {:.2} mJ",
                policy.name(),
                harvester.name(),
                c.emitted().count(),
                c.power_cycles,
                c.power_failures,
                pct(aic::coordinator::metrics::har_accuracy(&c)),
                c.app_energy * 1e3,
                c.state_energy * 1e3,
            );
        }
        "img" => {
            let spec = ImgRunSpec { horizon, trace_seed: seed, ..Default::default() };
            let c = experiment::run_img_policy_on(&spec, harvester.clone(), policy, &device);
            println!(
                "IMG {} on {}: {} results, {} cycles, {} failures, app {:.2} mJ, state {:.2} mJ",
                policy.name(),
                harvester.name(),
                c.emitted().count(),
                c.power_cycles,
                c.power_failures,
                c.app_energy * 1e3,
                c.state_energy * 1e3,
            );
        }
        _ => {
            eprintln!("error: unknown workload '{workload}' (expected har|img|audio)\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
