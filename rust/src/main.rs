//! `aic` — the Approximate Intermittent Computing coordinator CLI.
//!
//! Subcommands regenerate each figure of the paper (writing markdown to
//! stdout and CSV/JSON under `--out`), inspect the energy traces, check
//! the AOT artifacts through PJRT, and run free-form simulations.

use aic::coordinator::experiment::{
    self, fig12, fig4, har_latency_histograms, har_policy_comparison,
    img_trace_comparison, HarContext, HarRunSpec, ImgRunSpec,
};
use aic::coordinator::report::{f2, pct, ratio, Table};
use aic::energy::traces::{generate, TraceKind};
use aic::exec::Policy;
use aic::util::cli::Args;

const USAGE: &str = "aic — approximate intermittent computing (paper reproduction)

USAGE: aic <command> [--out out] [--fast] [options]

COMMANDS:
  fig4            expected vs measured accuracy vs feature count
  fig5            emulation: accuracy + throughput per policy
  fig6            emulation: latency distribution (power cycles)
  fig7            real-world: coherence + throughput vs continuous
  fig8            real-world: coherence + throughput vs Chinchilla
  fig9            real-world: latency distribution
  fig12           corner output vs perforation rate
  fig13           corner equivalence per energy trace
  fig14           imaging throughput per energy trace
  fig15           imaging latency distribution per trace
  all             every figure in sequence
  traces          synthetic energy trace statistics (Fig. 11)
  artifacts-check load + execute every AOT artifact through PJRT
  simulate        one campaign: --policy greedy|smartNN|chinchilla|alpaca|continuous
                  --trace rf|som|sim|sor|sir|kinetic --horizon secs

OPTIONS:
  --out DIR       output directory for CSV/JSON (default: out)
  --fast          smaller campaigns (CI-friendly)
  --seed N        base seed (default 42)
  --engine E      device integrator: analytic (default, event-driven) or
                  step (the fixed-step reference engine)
";

fn main() {
    let args = Args::from_env_with_flags(&["fast", "help"]);
    let out = args.get_or("out", "out").to_string();
    let fast = args.flag("fast");
    let seed = args.get_u64("seed", 42);
    // The integrator escape hatch: every campaign builds its engine via
    // EngineConfig::paper_default, which honours AIC_ENGINE.
    if let Some(spelling) = args.get("engine") {
        match aic::exec::engine::EngineKind::parse(spelling) {
            Some(kind) => std::env::set_var("AIC_ENGINE", kind.label()),
            None => {
                eprintln!("error: unknown engine '{spelling}' (expected analytic|step)\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        }
    }
    let cmd = args.command().unwrap_or("help").to_string();
    match cmd.as_str() {
        // fig4 always reports full-fidelity accuracy curves, even in
        // --fast sweeps (its cost is training, not campaigning).
        "fig4" => run_fig4(&context(seed, false), &out),
        "fig5" | "fig6" => run_fig56(&context(seed, fast), &out, fast, &cmd),
        "fig7" | "fig8" | "fig9" => run_fig789(&context(seed, fast), &out, fast, &cmd),
        "fig12" => run_fig12(&out, fast),
        "fig13" | "fig14" | "fig15" => run_fig131415(&out, seed, fast, &cmd),
        "all" => {
            // One HAR context for the whole sweep: the corpus, the
            // trained OVR SVM and the fitted class model are identical
            // across figs. 4-9, so train once and share read-only
            // across every figure's fleet jobs.
            let ctx = context(seed, fast);
            if fast {
                // Keep fig4 full-fidelity (see the single-command arm).
                run_fig4(&context(seed, false), &out);
            } else {
                run_fig4(&ctx, &out);
            }
            run_fig56(&ctx, &out, fast, "fig5");
            run_fig56(&ctx, &out, fast, "fig6");
            run_fig789(&ctx, &out, fast, "fig7");
            run_fig789(&ctx, &out, fast, "fig8");
            run_fig789(&ctx, &out, fast, "fig9");
            run_fig12(&out, fast);
            run_fig131415(&out, seed, fast, "fig13");
            run_fig131415(&out, seed, fast, "fig14");
            run_fig131415(&out, seed, fast, "fig15");
        }
        "traces" => run_traces(&out, seed),
        "artifacts-check" => run_artifacts_check(args.get_or("artifacts", "artifacts")),
        "simulate" => run_simulate(&args, seed),
        _ => print!("{USAGE}"),
    }
}

fn context(seed: u64, fast: bool) -> HarContext {
    if fast {
        experiment::test_context()
    } else {
        HarContext::build(seed)
    }
}

fn volunteers(fast: bool) -> Vec<u64> {
    if fast {
        vec![1, 2]
    } else {
        vec![1, 2, 3, 4, 5, 6]
    }
}

fn har_spec(fast: bool) -> HarRunSpec {
    HarRunSpec {
        horizon: if fast { 1800.0 } else { 4.0 * 3600.0 },
        ..Default::default()
    }
}

fn run_fig4(ctx: &HarContext, out: &str) {
    let ps: Vec<usize> = (0..=140).step_by(10).collect();
    let rows = fig4(ctx, &ps);
    let mut t = Table::new(
        "Fig. 4 — expected vs measured accuracy vs number of features",
        &["features", "expected", "measured"],
    );
    for r in rows {
        t.push(vec![r.p.to_string(), pct(r.expected), pct(r.measured)]);
    }
    t.emit(out, "fig4").expect("write fig4");
}

fn run_fig56(ctx: &HarContext, out: &str, fast: bool, which: &str) {
    let spec = har_spec(fast);
    if which == "fig5" {
        let rows = har_policy_comparison(ctx, &spec, &volunteers(fast));
        let mut t = Table::new(
            "Fig. 5 — emulation: accuracy and throughput normalised to continuous",
            &["policy", "accuracy", "thrpt vs continuous", "mean features", "state energy"],
        );
        for r in rows {
            t.push(vec![
                r.policy.name(),
                pct(r.accuracy),
                pct(r.throughput_vs_continuous),
                f2(r.mean_features),
                pct(r.state_energy_fraction),
            ]);
        }
        t.emit(out, "fig5").expect("write fig5");
    } else {
        let hists = har_latency_histograms(ctx, &spec, &volunteers(fast), 40);
        let mut t = Table::new(
            "Fig. 6 — emulation: latency distribution in power cycles",
            &["policy", "cycle0", "cycle1", "cycle2-5", "cycle6-15", "cycle16+"],
        );
        for (policy, h) in hists {
            let range =
                |a: usize, b: usize| -> f64 { (a..b.min(h.bins.len())).map(|i| h.frac(i)).sum() };
            t.push(vec![
                policy.name(),
                pct(h.frac(0)),
                pct(h.frac(1)),
                pct(range(2, 6)),
                pct(range(6, 16)),
                pct(range(16, 40) + h.overflow as f64 / h.count.max(1) as f64),
            ]);
        }
        t.emit(out, "fig6").expect("write fig6");
    }
}

fn run_fig789(ctx: &HarContext, out: &str, fast: bool, which: &str) {
    let spec = har_spec(fast);
    match which {
        "fig7" => {
            let rows = har_policy_comparison(ctx, &spec, &volunteers(fast));
            let mut t = Table::new(
                "Fig. 7 — real-world: coherence and throughput vs continuous",
                &["policy", "coherence vs continuous", "thrpt vs continuous"],
            );
            for r in rows.iter().filter(|r| !matches!(r.policy, Policy::Continuous)) {
                t.push(vec![
                    r.policy.name(),
                    pct(r.coherence_vs_continuous),
                    pct(r.throughput_vs_continuous),
                ]);
            }
            t.emit(out, "fig7").expect("write fig7");
        }
        "fig8" => {
            let rows = har_policy_comparison(ctx, &spec, &volunteers(fast));
            let mut t = Table::new(
                "Fig. 8 — real-world: coherence vs Chinchilla, throughput vs GREEDY",
                &["policy", "coherence vs chinchilla", "thrpt vs greedy", "thrpt vs chinchilla"],
            );
            for r in rows.iter().filter(|r| !matches!(r.policy, Policy::Continuous)) {
                t.push(vec![
                    r.policy.name(),
                    pct(r.coherence_vs_chinchilla),
                    pct(r.throughput_vs_greedy),
                    ratio(r.throughput_vs_chinchilla),
                ]);
            }
            t.emit(out, "fig8").expect("write fig8");
        }
        _ => {
            let hists = har_latency_histograms(ctx, &spec, &volunteers(fast), 40);
            let mut t = Table::new(
                "Fig. 9 — real-world: latency distribution in power cycles",
                &["policy", "same cycle", "1 cycle", "2+ cycles"],
            );
            for (policy, h) in hists {
                let rest: f64 = (2..h.bins.len()).map(|i| h.frac(i)).sum::<f64>()
                    + h.overflow as f64 / h.count.max(1) as f64;
                t.push(vec![policy.name(), pct(h.frac(0)), pct(h.frac(1)), pct(rest)]);
            }
            t.emit(out, "fig9").expect("write fig9");
        }
    }
}

fn run_fig12(out: &str, fast: bool) {
    let size = if fast { 96 } else { aic::imgproc::images::EVAL_SIZE };
    let rows = fig12(size, &[0.0, 0.2, 0.42, 0.55, 0.7, 0.85]);
    let mut t = Table::new(
        "Fig. 12 — corner detection output vs fraction of loop iterations skipped",
        &["picture", "skipped", "corners", "reference", "equivalent"],
    );
    for r in rows {
        t.push(vec![
            r.picture.name().to_string(),
            pct(r.skip_fraction),
            r.corners.to_string(),
            r.reference_corners.to_string(),
            r.equivalent.to_string(),
        ]);
    }
    t.emit(out, "fig12").expect("write fig12");
}

fn run_fig131415(out: &str, seed: u64, fast: bool, which: &str) {
    let spec = ImgRunSpec {
        horizon: if fast { 1200.0 } else { 2.0 * 3600.0 },
        trace_seed: seed,
        ..Default::default()
    };
    let rows = img_trace_comparison(&spec);
    match which {
        "fig13" => {
            let mut t = Table::new(
                "Fig. 13 — corner info equivalent to a continuous execution",
                &["picture", "equivalent corner info (pooled over traces)"],
            );
            for (picture, eq) in experiment::fig13_by_picture(&spec) {
                t.push(vec![picture.name().to_string(), pct(eq)]);
            }
            let mut per_trace = Table::new(
                "Fig. 13 (suppl.) — equivalence per energy trace",
                &["trace", "equivalent corner info"],
            );
            for r in &rows {
                per_trace.push(vec![r.trace.name().to_string(), pct(r.equivalence_aic)]);
            }
            t.emit(out, "fig13").expect("write fig13");
            per_trace.emit(out, "fig13_per_trace").expect("write fig13 suppl");
        }
        "fig14" => {
            let mut t = Table::new(
                "Fig. 14 — imaging throughput normalised to continuous",
                &["trace", "AIC", "Chinchilla", "AIC/Chinchilla"],
            );
            for r in &rows {
                let gain = if r.throughput_chinchilla_vs_continuous > 0.0 {
                    r.throughput_aic_vs_continuous / r.throughput_chinchilla_vs_continuous
                } else {
                    f64::INFINITY
                };
                t.push(vec![
                    r.trace.name().to_string(),
                    pct(r.throughput_aic_vs_continuous),
                    pct(r.throughput_chinchilla_vs_continuous),
                    ratio(gain),
                ]);
            }
            t.emit(out, "fig14").expect("write fig14");
        }
        _ => {
            let mut t = Table::new(
                "Fig. 15 — latency to produce the corner output (power cycles)",
                &["trace", "AIC same-cycle", "Chinchilla mean latency"],
            );
            for r in &rows {
                t.push(vec![
                    r.trace.name().to_string(),
                    pct(r.aic_same_cycle),
                    f2(r.chinchilla_latency_mean),
                ]);
            }
            t.emit(out, "fig15").expect("write fig15");
        }
    }
}

fn run_traces(out: &str, seed: u64) {
    let mut t = Table::new(
        "Fig. 11 — synthetic energy traces",
        &["trace", "mean power (uW)", "total energy (J/h)", "variability (cv)"],
    );
    for kind in TraceKind::ALL {
        let tr = generate(kind, 3600.0, 0.01, seed);
        t.push(vec![
            kind.name().to_string(),
            format!("{:.1}", tr.mean_power() * 1e6),
            format!("{:.3}", tr.total_energy()),
            f2(tr.variability()),
        ]);
    }
    t.emit(out, "fig11_traces").expect("write traces");
}

fn run_artifacts_check(dir: &str) {
    use aic::runtime::{ArtifactRuntime, Tensor};
    let rt = match ArtifactRuntime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact load failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!("loaded {} artifacts on {} device(s)", rt.names().len(), rt.device_count());
    for name in rt.names() {
        let shapes = rt.input_shapes(&name);
        let inputs: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s.clone())).collect();
        match rt.execute(&name, &inputs) {
            Ok(out) => println!("  {name}: inputs {shapes:?} -> output {:?} OK", out.shape),
            Err(e) => {
                eprintln!("  {name}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("artifacts-check OK");
}

fn run_simulate(args: &Args, seed: u64) {
    // Unknown names are an error, not a silent Greedy fallback.
    let policy: Policy = match args.get_or("policy", "greedy").parse() {
        Ok(policy) => policy,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    let horizon = args.get_f64("horizon", 3600.0);
    let trace = args.get_or("trace", "kinetic").to_string();
    if trace == "kinetic" {
        let ctx = HarContext::build(seed ^ 0xC0FFEE);
        let spec = HarRunSpec { horizon, sample_period: 60.0, script_seed: seed };
        let c = experiment::run_har_policy(&ctx, &spec, policy);
        println!(
            "HAR {}: {} results, {} cycles, {} failures, acc {}, app {:.2} mJ, state {:.2} mJ",
            policy.name(),
            c.emitted().count(),
            c.power_cycles,
            c.power_failures,
            pct(aic::coordinator::metrics::har_accuracy(&c)),
            c.app_energy * 1e3,
            c.state_energy * 1e3,
        );
    } else {
        // Like --policy: an unknown trace is an error, not a silent Som.
        let kind = match TraceKind::from_name(&trace) {
            Some(kind) => kind,
            None => {
                eprintln!("error: unknown trace '{trace}' (expected rf|som|sim|sor|sir|kinetic)\n");
                eprint!("{USAGE}");
                std::process::exit(2);
            }
        };
        let spec = ImgRunSpec { horizon, trace_seed: seed, ..Default::default() };
        let c = experiment::run_img_policy(&spec, kind, policy);
        println!(
            "IMG {} on {}: {} results, {} cycles, {} failures, app {:.2} mJ, state {:.2} mJ",
            policy.name(),
            kind.name(),
            c.emitted().count(),
            c.power_cycles,
            c.power_failures,
            c.app_energy * 1e3,
            c.state_energy * 1e3,
        );
    }
}
