//! Adaptive-policy overhead bench (the learner's §Perf deliverable).
//!
//! The adaptive runtime adds three costs on top of the static SMART
//! path: the per-cycle EWMA predictor update, the per-round UCB arm
//! selection/reward, and the few words of learned state it persists
//! through the energy ledger. The first two are timed as microbenches
//! (they run once per power cycle / round, so nanoseconds matter at
//! sweep scale); the end-to-end cost shows up as the adaptive grid's
//! fleet time next to an identical grid with the learner swapped for
//! static SMART.
//!
//! Honours `AIC_ENGINE` (the CI matrix times both integrators),
//! `AIC_BENCH_FAST` (CI smoke) and `AIC_BENCH_OUT` (JSON artifact).

use aic::coordinator::experiment::SupplyCache;
use aic::coordinator::scenario::{HarvesterSpec, Projection, Scenario, WorkloadSpec};
use aic::energy::predictor::EwmaPredictor;
use aic::energy::synth::SynthSpec;
use aic::exec::adaptive::{LearnedState, DEFAULT_ALPHA, DEFAULT_EXPLORE};
use aic::exec::Policy;
use aic::util::bench::{black_box, Bench};

fn grid(policies: Vec<Policy>) -> Scenario {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    Scenario::new("adaptive_env", WorkloadSpec::Audio)
        .with_title("adaptive-learner timing grid")
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
        .with_policies(policies)
        .with_seeds(if fast { vec![1] } else { vec![1, 2, 3] })
        .with_horizon(if fast { 300.0 } else { 900.0 })
        .with_sample_period(30.0)
        .with_projection(Projection::Pareto)
}

fn main() {
    let b = Bench::new("adaptive_env");

    // Predictor: one EWMA update per power cycle. A bursty supply can
    // produce thousands of cycles per simulated hour, so this is on the
    // sweep hotpath.
    b.bench_throughput("learner/ewma_observe_1k", 1000, || {
        let mut p = EwmaPredictor::new(DEFAULT_ALPHA);
        for i in 0..1000u64 {
            let budget = 1.2e-4 + 1e-7 * (i % 17) as f64;
            p.observe(budget, i as f64 * 2.5);
        }
        black_box(p.energy_or(0.0));
    });

    // Bandit: select + reward per emitted round, over the 4-arm depth
    // menu with the deterministic tie-break.
    b.bench_throughput("learner/ucb_round_1k", 1000, || {
        let mut s = LearnedState::new(DEFAULT_ALPHA);
        for i in 0..1000u64 {
            let arm = s.select_arm(DEFAULT_EXPLORE);
            s.reward(arm, 0.6 + 0.1 * (i % 3) as f64);
        }
        black_box(s.plays);
    });

    // End-to-end: the learner's fleet time next to the identical grid
    // with static SMART in its slot — the delta is what per-cycle
    // persistence plus the bandit actually cost a sweep.
    let cache = SupplyCache::new();
    let adaptive = grid(vec![
        Policy::Greedy,
        Policy::Adaptive { alpha: DEFAULT_ALPHA, explore: DEFAULT_EXPLORE },
    ]);
    b.bench("fleet_adaptive_grid", || {
        let run = adaptive.run_cached(false, None, None, &cache);
        black_box(run.pareto_rows().len());
    });
    let stat = grid(vec![Policy::Greedy, Policy::Smart { bound: 0.80 }]);
    b.bench("fleet_static_grid", || {
        let run = stat.run_cached(false, None, None, &cache);
        black_box(run.pareto_rows().len());
    });
}
