//! Hot-path microbenchmarks (the §Perf deliverable, L3 side).
//!
//! Covers the operations dominating campaign wall-clock: the engine's
//! charge integration and op execution, the anytime scoring step, full
//! feature extraction, one Harris row, SVM training, and the PJRT
//! artifact execution path (batched replay).

use aic::energy::harvester::Harvester;
use aic::energy::mcu::OpCost;
use aic::exec::engine::{Engine, EngineConfig, Ledger};
use aic::har::dataset::{generate_window, Volunteer};
use aic::har::features::extract_all;
use aic::har::Activity;
use aic::imgproc::harris::{gradients, response_row, HarrisConfig, ResponseMap};
use aic::imgproc::images::{render, Picture};
use aic::svm::train::{train_ovr, TrainConfig};
use aic::util::bench::{black_box, Bench};
use aic::util::rng::Rng;

fn main() {
    let b = Bench::new("hotpath");
    // The engine benches honour AIC_ENGINE: `AIC_ENGINE=step` times the
    // fixed-step reference integrator (the BENCH_before baseline),
    // unset/`analytic` times the event-driven engine.

    // Engine: charge integration (dominates long recharge ramps).
    {
        let mut cfg = EngineConfig::paper_default(1e9);
        cfg.initial_voltage = 0.0;
        let trace = aic::energy::traces::generate(
            aic::energy::traces::TraceKind::Sim,
            600.0,
            0.01,
            1,
        );
        let mut e = Engine::new(cfg, Harvester::Replay(trace));
        b.bench_throughput("engine/charge_until_boot", 1, || {
            e.cap.set_voltage(0.5);
            e.now = 0.0;
            black_box(e.charge_until_boot());
        });
    }

    // Engine: op execution (the per-step hot loop) on a replay supply.
    {
        let trace = aic::energy::traces::generate(
            aic::energy::traces::TraceKind::Sim,
            600.0,
            0.01,
            2,
        );
        let mut e = Engine::new(
            EngineConfig::paper_default(1e12),
            Harvester::Replay(trace),
        );
        let cost = OpCost::cycles(10_000);
        b.bench_throughput("engine/run_op_x1000", 1000, || {
            for _ in 0..1000 {
                black_box(e.run_op(&cost, Ledger::App));
            }
            e.cap.set_voltage(3.2);
        });
    }

    // Engine: one hour of LPM3 sleep (dominates inter-slot idling).
    {
        let trace = aic::energy::traces::generate(
            aic::energy::traces::TraceKind::Sim,
            600.0,
            0.01,
            3,
        );
        let mut e = Engine::new(
            EngineConfig::paper_default(1e12),
            Harvester::Replay(trace),
        );
        b.bench_throughput("engine/sleep_3600s", 3600, || {
            e.cap.set_voltage(3.3);
            e.now = 0.0;
            black_box(e.sleep(3600.0));
        });
    }

    // Anytime scoring step (6 classes).
    {
        let ctx = aic::coordinator::experiment::test_context();
        let mut rng = Rng::new(5);
        let who = Volunteer::sample(&mut rng);
        let w = generate_window(Activity::Walking, &who, &mut rng, 0.0);
        let feats = extract_all(&w);
        b.bench_throughput("svm/anytime_step_x140", 140, || {
            let mut st = ctx.asvm.begin();
            for _ in 0..140 {
                ctx.asvm.add_feature(&mut st, &feats);
            }
            black_box(st.scores[0]);
        });
    }

    // Full 140-feature extraction (dominates load_next).
    {
        let mut rng = Rng::new(6);
        let who = Volunteer::sample(&mut rng);
        let w = generate_window(Activity::Walking, &who, &mut rng, 0.0);
        b.bench("har/extract_all_140", || {
            black_box(extract_all(&w));
        });
    }

    // One Harris response row at eval size.
    {
        let img = render(Picture::Cluttered, 160, 160, 3);
        let (ix, iy) = gradients(&img);
        let cfg = HarrisConfig::default();
        let mut map = ResponseMap::new(160, 160);
        let mut y = 0usize;
        b.bench_throughput("imgproc/harris_row_160", 160, || {
            for _ in 0..160 {
                response_row(&ix, &iy, &mut map, y % 160, &cfg);
                y += 1;
            }
        });
    }

    // SVM training (offline path, sets context-build time).
    {
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<f64>> =
            (0..300).map(|_| (0..140).map(|_| rng.gaussian()).collect()).collect();
        let labels: Vec<usize> = (0..300).map(|i| i % 6).collect();
        b.bench("svm/train_300x140", || {
            black_box(train_ovr(&rows, &labels, 6, &TrainConfig::default()));
        });
    }

    // PJRT artifact execution (batched replay), when artifacts exist.
    match aic::runtime::ArtifactRuntime::load("artifacts") {
        Ok(rt) => {
            use aic::runtime::Tensor;
            let x = Tensor::zeros(vec![256, 140]);
            let w = Tensor::zeros(vec![6, 140]);
            let bias = Tensor::zeros(vec![6]);
            let mask = Tensor::new(
                vec![140],
                (0..140).map(|i| if i < 70 { 1.0 } else { 0.0 }).collect(),
            );
            b.bench_throughput("pjrt/svm_prefix_b256", 256, || {
                black_box(
                    rt.execute("svm_prefix", &[x.clone(), w.clone(), bias.clone(), mask.clone()])
                        .unwrap(),
                );
            });
            let img = Tensor::zeros(vec![160, 160]);
            let rmask = Tensor::new(vec![160], vec![1.0; 160]);
            b.bench("pjrt/harris_160", || {
                black_box(rt.execute("harris", &[img.clone(), rmask.clone()]).unwrap());
            });
        }
        Err(e) => println!("(pjrt benches skipped: {e})"),
    }
}
