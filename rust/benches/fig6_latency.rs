//! Fig. 6 bench: emulation — distribution of the latency to return the
//! classification, in power cycles between acquisition and emission.
//!
//! Paper shape: approximate intermittent computing always returns the
//! result within the same power cycle (by design); Chinchilla's latency
//! is a function of energy patterns, with a tail reaching tens of cycles.

use aic::coordinator::scenario::builtin;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig6_latency");
    let mut sc = builtin("fig6", 42)
        .expect("fig6 scenario")
        .with_seeds(if fast { vec![1] } else { vec![1, 2, 3, 4] });
    if fast {
        sc = sc.with_horizon(1800.0);
    }
    let ctx = sc.har_context();

    let mut hists = Vec::new();
    b.bench("latency_distributions", || {
        hists = sc.run_with(false, Some(&ctx), None).latency_histograms(40);
    });

    let rows: Vec<Vec<String>> = hists
        .iter()
        .map(|(policy, h)| {
            let tail: f64 = (6..h.bins.len()).map(|i| h.frac(i)).sum::<f64>()
                + h.overflow as f64 / h.count.max(1) as f64;
            vec![
                policy.name(),
                format!("{:.1}%", 100.0 * h.frac(0)),
                format!("{:.1}%", 100.0 * h.frac(1)),
                format!("{:.1}%", 100.0 * (2..6).map(|i| h.frac(i)).sum::<f64>()),
                format!("{:.1}%", 100.0 * tail),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 6 — latency distribution (power cycles)",
        &["policy", "0 cycles", "1 cycle", "2-5", "6+"],
        &rows,
    );

    for (policy, h) in &hists {
        match policy {
            Policy::Greedy | Policy::Smart { .. } => println!(
                "shape: {} same-cycle by design [{}]",
                policy.name(),
                if h.frac(0) > 0.999 { "PASS" } else { "FAIL" }
            ),
            Policy::Chinchilla => {
                let multi: f64 = 1.0 - h.frac(0);
                println!(
                    "shape: chinchilla stretches across cycles ({:.0}%) [{}]",
                    100.0 * multi,
                    if multi > 0.2 { "PASS" } else { "FAIL" }
                );
            }
            _ => {}
        }
    }
}
