//! Fig. 8 bench: real-world experiments — coherence of the approximate
//! classification against Chinchilla, and throughput normalised to
//! GREEDY (§5.4).
//!
//! Paper shape: coherence mirrors Fig. 7 (Chinchilla processes all
//! features like a continuous execution); Chinchilla's throughput is a
//! small fraction of GREEDY's because single samples stretch across
//! power cycles, preventing the acquisition of newer samples.

use aic::coordinator::scenario::builtin;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig8_chinchilla");
    // §5.4: another six volunteers, ~58 h each; scaled-down horizon.
    let sc = builtin("fig8", 42)
        .expect("fig8 scenario")
        .with_horizon(if fast { 1800.0 } else { 6.0 * 3600.0 })
        .with_seeds(if fast { vec![21, 22] } else { vec![21, 22, 23, 24, 25, 26] });
    let ctx = sc.har_context();

    let mut rows_out = Vec::new();
    b.bench("chinchilla_pair_campaigns", || {
        rows_out = sc.run_with(false, Some(&ctx), None).policy_rows();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .filter(|r| !matches!(r.policy, Policy::Continuous))
        .map(|r| {
            vec![
                r.policy.name(),
                format!("{:.1}%", 100.0 * r.coherence_vs_chinchilla),
                format!("{:.1}%", 100.0 * r.throughput_vs_greedy),
                format!("{:.2}x", r.throughput_vs_chinchilla),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 8 — coherence vs Chinchilla, throughput vs GREEDY",
        &["policy", "coherence vs chinchilla", "thrpt vs greedy", "thrpt vs chinchilla"],
        &rows,
    );

    let get = |p: Policy| rows_out.iter().find(|r| r.policy == p).unwrap();
    let greedy = get(Policy::Greedy);
    println!(
        "shape: headline throughput gain over Chinchilla = {:.1}x [{}]",
        greedy.throughput_vs_chinchilla,
        if greedy.throughput_vs_chinchilla >= 2.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: greedy tops throughput [{}]",
        if rows_out
            .iter()
            .filter(|r| !matches!(r.policy, Policy::Continuous))
            .all(|r| r.throughput_vs_greedy <= 1.0 + 1e-9)
        {
            "PASS"
        } else {
            "FAIL"
        }
    );
    let alpaca = get(Policy::Alpaca);
    println!(
        "shape: greedy also beats the task-based baseline ({:.1}x alpaca) [{}]",
        1.0 / alpaca.throughput_vs_greedy.max(1e-9),
        if alpaca.throughput_vs_greedy <= 1.0 + 1e-9 { "PASS" } else { "FAIL" }
    );
}
