//! Synthetic-environment hotpath bench.
//!
//! Times the two costs a synth sweep adds on top of a replay sweep:
//! realising an environment family member (`SynthSpec::build` — the
//! per-cell generation step a 100-seed grid pays 100 times), and the
//! analytic engine stepping over the generated composite (charge ramps
//! and hour-long sleeps on a multi-source switchover supply). The
//! engine legs honour `AIC_ENGINE`, so `AIC_ENGINE=step` measures the
//! fixed-step reference on the same supplies.

use aic::energy::harvester::Harvester;
use aic::energy::synth::SynthSpec;
use aic::exec::engine::{Engine, EngineConfig};
use aic::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::new("synth_env");

    // Generation: single-source and 4-source composite families.
    let solar = SynthSpec::builtin_solar();
    b.bench("synth/build_solar_1800s", || {
        black_box(solar.build(1));
    });
    let multi = SynthSpec::builtin_multi();
    b.bench("synth/build_multi_1800s", || {
        black_box(multi.build(1));
    });

    // Engine: recharge ramp on the composite supply (the synth twin of
    // engine/charge_until_boot).
    {
        let mut cfg = EngineConfig::paper_default(1e9);
        cfg.initial_voltage = 0.0;
        let mut e = Engine::new(cfg, Harvester::Synth(multi.build(2)));
        b.bench_throughput("synth/charge_until_boot", 1, || {
            e.cap.set_voltage(0.5);
            e.now = 0.0;
            black_box(e.charge_until_boot());
        });
    }

    // Engine: one hour of LPM3 sleep over the composite segments (the
    // O(events) claim under test — a sampled supply would be ~100x the
    // events).
    {
        let mut e = Engine::new(
            EngineConfig::paper_default(1e12),
            Harvester::Synth(multi.build(3)),
        );
        b.bench_throughput("synth/sleep_3600s", 3600, || {
            e.cap.set_voltage(3.3);
            e.now = 0.0;
            black_box(e.sleep(3600.0));
        });
    }
}
