//! Fig. 9 bench: real-world experiments — distribution of the latency to
//! return the classification (power cycles), §5.4.
//!
//! Paper shape: identical story to Fig. 6 on the real-world setup —
//! approximate intermittent computing returns the classification before
//! the first power failure; Chinchilla stretches across multiple cycles,
//! including recharge periods.

use aic::coordinator::scenario::builtin;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig9_latency_rw");
    // Real-world cohort: its own training seed and volunteers.
    let sc = builtin("fig9", 43)
        .expect("fig9 scenario")
        .with_horizon(if fast { 1800.0 } else { 6.0 * 3600.0 })
        .with_seeds(if fast { vec![31] } else { vec![31, 32, 33, 34] });
    let ctx = sc.har_context();

    let mut hists = Vec::new();
    b.bench("rw_latency_distributions", || {
        hists = sc.run_with(false, Some(&ctx), None).latency_histograms(40);
    });

    let rows: Vec<Vec<String>> = hists
        .iter()
        .map(|(policy, h)| {
            let p95 = {
                let mut acc = 0.0;
                let mut v = h.bins.len() as f64;
                for i in 0..h.bins.len() {
                    acc += h.frac(i);
                    if acc >= 0.95 {
                        v = i as f64;
                        break;
                    }
                }
                v
            };
            vec![
                policy.name(),
                format!("{:.1}%", 100.0 * h.frac(0)),
                format!("{:.1}%", 100.0 * (1.0 - h.frac(0))),
                format!("{p95:.0}"),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 9 — real-world latency distribution",
        &["policy", "same cycle", "later cycles", "p95 (cycles)"],
        &rows,
    );

    for (policy, h) in &hists {
        if matches!(policy, Policy::Greedy | Policy::Smart { .. }) {
            println!(
                "shape: {} emits before first power failure [{}]",
                policy.name(),
                if h.frac(0) > 0.999 { "PASS" } else { "FAIL" }
            );
        }
    }
}
