//! Sweep-scale benchmark (the §Perf deliverable, sweep side).
//!
//! Times the full scenario pipeline — plan expansion and the fleet run
//! of an audio campaign grid over built-in synthetic environments —
//! with the per-sweep supply cache on and off. The grid is shaped so
//! supply materialisation matters: every (policy) cell of a
//! (harvester, seed) unit resolves to the same supply, so the cached
//! run builds half as many harvesters/stepping tables as the uncached
//! one (`AIC_SUPPLY_CACHE=off` reaches the same uncached path through
//! `Scenario::run`; here both modes are driven programmatically).
//!
//! Honours `AIC_ENGINE` (the CI matrix times both integrators),
//! `AIC_BENCH_FAST` (CI smoke) and `AIC_BENCH_OUT` (JSON artifact).

use aic::coordinator::experiment::SupplyCache;
use aic::coordinator::scenario::{HarvesterSpec, Projection, Scenario, WorkloadSpec};
use aic::energy::synth::SynthSpec;
use aic::exec::Policy;
use aic::util::bench::{black_box, Bench};

fn grid() -> Scenario {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    Scenario::new("sweep_scale", WorkloadSpec::Audio)
        .with_title("sweep-scale timing grid")
        .with_harvesters(vec![
            HarvesterSpec::Synth(SynthSpec::builtin_multi()),
            HarvesterSpec::Synth(SynthSpec::builtin_solar()),
        ])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_seeds(if fast { vec![1] } else { vec![1, 2, 3] })
        .with_horizon(if fast { 300.0 } else { 900.0 })
        .with_sample_period(30.0)
        .with_projection(Projection::AudioSummary)
}

fn main() {
    let b = Bench::new("sweep_scale");
    let scenario = grid();

    // Plan expansion: the pure-spec side of the pipeline.
    b.bench("plan", || {
        black_box(scenario.plan().len());
    });

    // Fleet with the per-sweep supply cache (the `Scenario::run`
    // default): distinct (harvester, seed, booster) supplies are
    // materialised once and shared across policy cells and workers.
    let mut builds_cached = 0;
    b.bench("fleet_synth_grid_cached", || {
        let cache = SupplyCache::new();
        let run = scenario.run_cached(false, None, None, &cache);
        builds_cached = cache.builds();
        black_box(run.audio_campaigns().len());
    });

    // Same grid with sharing disabled: every cell builds its own supply
    // (the `AIC_SUPPLY_CACHE=off` behaviour).
    let mut builds_uncached = 0;
    b.bench("fleet_synth_grid_uncached", || {
        let cache = SupplyCache::disabled();
        let run = scenario.run_cached(false, None, None, &cache);
        builds_uncached = cache.builds();
        black_box(run.audio_campaigns().len());
    });

    let cells = scenario.plan().len();
    println!(
        "(supply builds: cached {builds_cached} vs uncached {builds_uncached} over {cells} cells)"
    );
}
