//! Fleet delta-sync bench (the sync layer's §Perf deliverable).
//!
//! Three costs matter at sweep scale: the per-observation column write
//! (version bump + cell upsert + log append), the pairwise exchange
//! (delta extraction, join, ack gossip, GC), and the end-to-end fleet
//! cell (event loop over observations and rendezvous). The first two
//! are microbenches — a 600-meeting cell performs thousands of them —
//! and the cell bench is the number a `fleet_*` sweep multiplies by its
//! grid size.
//!
//! Honours `AIC_BENCH_FAST` (CI smoke) and `AIC_BENCH_OUT` (JSON
//! artifact). The sync layer never touches the device integrator, so
//! there is no `AIC_ENGINE` axis here.

use aic::coordinator::sync::{exchange, run_fleet_cell, FleetSpec, Replica};
use aic::energy::harvester::Harvester;
use aic::util::bench::{black_box, Bench};

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fleet_sync");

    // Column writes: one version bump + cell upsert + log append each.
    // Every observation a device makes lands here.
    b.bench_throughput("sync/write_1k", 1000, || {
        let mut r = Replica::new(0, 4);
        for i in 0..1000u32 {
            r.write(i % 64, (i % 3) as u8, i as f64 * 0.5);
        }
        black_box(r.log_entries());
    });

    // One rendezvous between two replicas with fresh divergence: delta
    // extraction both ways, join, ack gossip, GC.
    b.bench_throughput("sync/exchange_100", 100, || {
        let mut a = Replica::new(0, 2);
        let mut c = Replica::new(1, 2);
        let mut bytes = 0u64;
        for round in 0..100u32 {
            for w in 0..8u32 {
                a.write(round * 8 + w, 0, w as f64);
                c.write(round * 8 + w, 1, w as f64 + 0.5);
            }
            bytes += exchange(&mut a, &mut c).bytes;
        }
        black_box(bytes);
    });

    // GC pressure: a triangle where one replica lags, then catches up —
    // the ack matrix and prune walk at their least favourable.
    b.bench_throughput("sync/gc_triangle_100", 100, || {
        let mut pruned = 0u64;
        for _ in 0..100 {
            let mut fleet: Vec<Replica> = (0..3).map(|i| Replica::new(i, 3)).collect();
            for i in 0..3usize {
                for w in 0..16u32 {
                    fleet[i].write(w, i as u8, w as f64);
                }
            }
            for &(i, j) in &[(0, 1), (0, 1), (1, 2), (0, 2), (0, 1), (1, 2)] {
                let (lo, hi) = fleet.split_at_mut(j);
                exchange(&mut lo[i], &mut hi[0]);
            }
            pruned += fleet.iter().map(|r| r.gc_pruned).sum::<u64>();
        }
        black_box(pruned);
    });

    // End-to-end: one fleet cell on constant supplies (every meeting
    // happens, so this is the dense upper bound a sweep cell costs).
    let spec = FleetSpec { devices: if fast { 4 } else { 8 }, ..FleetSpec::default() };
    let horizon = if fast { 600.0 } else { 1800.0 };
    let supplies: Vec<Harvester> =
        (0..spec.devices).map(|_| Harvester::Constant(2.0e-3)).collect();
    b.bench("fleet_cell_constant", || {
        let f = run_fleet_cell(&spec, &supplies, horizon, 42);
        black_box((f.meetings, f.bytes));
    });
}
