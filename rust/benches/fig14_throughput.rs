//! Fig. 14 bench: imaging system throughput normalised to a continuous
//! execution, per energy trace, AIC vs Chinchilla.
//!
//! Paper shape: AIC constantly outperforms Chinchilla (5x headline);
//! traces richer in energy amplify AIC's gains; RF and SIR — equal total
//! energy, opposite dynamics — perform similarly under AIC while
//! Chinchilla suffers on RF's rapid dynamics.

use aic::coordinator::scenario::{builtin, HarvesterSpec, ImgTraceRow};
use aic::energy::traces::TraceKind;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig14_throughput");
    // Historical bench realisation: trace seed 3 (the old ImgRunSpec
    // default); --fast shrinks the horizon via the scenario's fast mode.
    let sc = builtin("fig14", 3).expect("fig14 scenario");

    let mut rows_out: Vec<ImgTraceRow> = Vec::new();
    b.bench("per_trace_campaigns", || {
        rows_out = sc.run(fast).img_trace_rows();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            let gain = r.throughput_aic_vs_continuous
                / r.throughput_chinchilla_vs_continuous.max(1e-9);
            vec![
                r.harvester.name(),
                format!("{:.1}%", 100.0 * r.throughput_aic_vs_continuous),
                format!("{:.1}%", 100.0 * r.throughput_chinchilla_vs_continuous),
                format!("{gain:.2}x"),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 14 — normalised throughput per trace",
        &["trace", "AIC", "Chinchilla", "gain"],
        &rows,
    );

    let get = |k: TraceKind| {
        rows_out.iter().find(|r| r.harvester == HarvesterSpec::Ambient(k)).unwrap()
    };
    let all_win = rows_out
        .iter()
        .all(|r| r.throughput_aic_vs_continuous >= r.throughput_chinchilla_vs_continuous);
    println!("shape: AIC wins on every trace [{}]", if all_win { "PASS" } else { "FAIL" });
    let rf = get(TraceKind::Rf);
    let sir = get(TraceKind::Sir);
    let rf_sir_close = (rf.throughput_aic_vs_continuous - sir.throughput_aic_vs_continuous)
        .abs()
        < 0.5 * sir.throughput_aic_vs_continuous.max(0.02);
    println!(
        "shape: AIC on RF ~ SIR (same total energy) [{}]",
        if rf_sir_close { "PASS" } else { "FAIL" }
    );
    let chin_rf_hurts = rf.throughput_chinchilla_vs_continuous
        <= sir.throughput_chinchilla_vs_continuous + 1e-9;
    println!(
        "shape: Chinchilla suffers on RF dynamics [{}]",
        if chin_rf_hurts { "PASS" } else { "FAIL" }
    );
    let som = get(TraceKind::Som);
    println!(
        "shape: richest trace (SOM) amplifies AIC gain [{}]",
        if som.throughput_aic_vs_continuous >= rf.throughput_aic_vs_continuous {
            "PASS"
        } else {
            "FAIL"
        }
    );
}
