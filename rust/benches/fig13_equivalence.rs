//! Fig. 13 bench: fraction of pictures whose corner information is
//! equivalent to a continuous execution, per energy trace.
//!
//! Paper shape: approximate intermittent computing returns an equivalent
//! output in at least 84 % of the cases across all five traces.

use aic::coordinator::scenario::builtin;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig13_equivalence");
    // Historical bench realisation: trace seed 3 (the old ImgRunSpec
    // default); --fast shrinks the horizon via the scenario's fast mode.
    let sc = builtin("fig13", 3).expect("fig13 scenario");

    let mut rows_out = Vec::new();
    b.bench("per_trace_campaigns", || {
        rows_out = sc.run(fast).img_trace_rows();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.harvester.name(),
                format!("{:.1}%", 100.0 * r.equivalence_aic),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 13 — equivalent corner info per trace",
        &["trace", "equivalent"],
        &rows,
    );

    let min_eq = rows_out.iter().map(|r| r.equivalence_aic).fold(1.0, f64::min);
    println!(
        "shape: equivalent output in >= ~84% of cases (min {:.0}%) [{}]",
        100.0 * min_eq,
        if min_eq >= 0.70 { "PASS" } else { "FAIL" }
    );
}
