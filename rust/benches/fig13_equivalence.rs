//! Fig. 13 bench: fraction of pictures whose corner information is
//! equivalent to a continuous execution, per energy trace.
//!
//! Paper shape: approximate intermittent computing returns an equivalent
//! output in at least 84 % of the cases across all five traces.

use aic::coordinator::experiment::{img_trace_comparison, ImgRunSpec};
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig13_equivalence");
    let spec = ImgRunSpec {
        horizon: if fast { 1200.0 } else { 2.0 * 3600.0 },
        ..Default::default()
    };

    let mut rows_out = Vec::new();
    b.bench("per_trace_campaigns", || {
        rows_out = img_trace_comparison(&spec);
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.trace.name().to_string(),
                format!("{:.1}%", 100.0 * r.equivalence_aic),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 13 — equivalent corner info per trace",
        &["trace", "equivalent"],
        &rows,
    );

    let min_eq = rows_out.iter().map(|r| r.equivalence_aic).fold(1.0, f64::min);
    println!(
        "shape: equivalent output in >= ~84% of cases (min {:.0}%) [{}]",
        100.0 * min_eq,
        if min_eq >= 0.70 { "PASS" } else { "FAIL" }
    );
}
