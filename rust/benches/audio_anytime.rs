//! Audio hot-path bench: the anytime acoustic event detection pipeline.
//!
//! Times the operations dominating an audio campaign's wall-clock — the
//! Goertzel refinement step, window synthesis, and threshold
//! classification — then runs the builtin audio grid and checks the
//! paper-shaped property: detection accuracy is monotonically
//! non-decreasing in completed refinement steps.

use aic::audio::detector::SpectralDetector;
use aic::audio::stream::{labelled_windows, AudioScript};
use aic::audio::NUM_PROBES;
use aic::coordinator::scenario::builtin;
use aic::energy::mcu::McuModel;
use aic::util::bench::{black_box, Bench};

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("audio_anytime");
    let detector = SpectralDetector::paper_default();

    // The refinement hot loop: all 63 Goertzel probes over one window.
    {
        let windows = labelled_windows(1, 3);
        let w = windows.last().unwrap();
        b.bench_throughput("audio/goertzel_probe_x63", NUM_PROBES as u64, || {
            let mut acc = 0.0;
            for j in 0..NUM_PROBES {
                acc += detector.probe(&w.samples, j);
            }
            black_box(acc);
        });
    }

    // Window synthesis (dominates load_next on script sources).
    {
        let script = AudioScript::generate(3600.0, 7);
        let mut t = 0.0;
        b.bench("audio/window_at", || {
            black_box(script.window_at(t).samples[0]);
            t += 30.0;
        });
    }

    // Threshold classification from a full probe table.
    {
        let windows = labelled_windows(1, 9);
        let powers: Vec<Vec<f64>> = windows
            .iter()
            .map(|w| (0..NUM_PROBES).map(|j| detector.probe(&w.samples, j)).collect())
            .collect();
        let mut i = 0usize;
        b.bench("audio/classify_full", || {
            black_box(detector.classify(&powers[i % powers.len()]));
            i += 1;
        });
    }

    // The builtin audio grid end-to-end (the campaign hot path).
    let sc = builtin("audio", 3).expect("audio scenario");
    let mut rows_out = Vec::new();
    b.bench("audio/builtin_grid", || {
        rows_out = sc.run(fast).audio_policy_rows();
    });
    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.policy.name(),
                format!("{:.1}%", 100.0 * r.accuracy),
                format!("{:.1}", r.mean_probes),
                format!("{:.1}%", 100.0 * r.same_cycle_fraction),
            ]
        })
        .collect();
    b.report_table(
        "Audio — detection accuracy / refinement depth per policy",
        &["policy", "accuracy", "mean probes", "same cycle"],
        &rows,
    );

    // Shape: the anytime knob — accuracy monotone in refinement steps,
    // priced monotone in energy through the estimator.
    let windows = labelled_windows(4, 0xBE9C4);
    let ps: Vec<usize> = (0..=NUM_PROBES).collect();
    let curve = detector.accuracy_curve(&windows, &ps);
    let monotone = curve.windows(2).all(|w| w[1] >= w[0] - 1e-12);
    let profile = aic::audio::app::smart_table(&detector, &McuModel::paper_default());
    let priced = profile
        .cumulative_energy
        .windows(2)
        .all(|w| w[1] > w[0]);
    println!(
        "shape: accuracy monotone non-decreasing in refinement steps \
         (start {:.0}%, end {:.0}%) and strictly priced [{}]",
        100.0 * curve[0],
        100.0 * curve[NUM_PROBES],
        if monotone && priced && curve[NUM_PROBES] >= 0.99 { "PASS" } else { "FAIL" }
    );
}
