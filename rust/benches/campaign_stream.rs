//! Streamed vs legacy campaign sweeps (the streaming-engine perf
//! deliverable).
//!
//! Times the same `cells`-projection audio grid over built-in synthetic
//! environments three ways — the legacy batch path (`run_cached` +
//! `tables()`), the streaming pipeline with no store, and the streaming
//! pipeline committing to / resuming from an experiment store — and
//! measures each leg's peak live heap through a counting global
//! allocator. The batch path must hold every campaign of the grid at
//! once; the streamed legs must peak at one chunk plus the accumulator,
//! independent of cell count (the `tests/alloc_hygiene.rs` gate, here at
//! benchmark scale: ~10k cells, or ~100 under `AIC_BENCH_FAST`).
//!
//! Honours `AIC_ENGINE`, `AIC_BENCH_FAST` and `AIC_BENCH_OUT` like every
//! other bench; peak-allocation rows are printed via `report_table`.

use aic::coordinator::experiment::SupplyCache;
use aic::coordinator::scenario::{HarvesterSpec, Projection, Scenario, WorkloadSpec};
use aic::coordinator::sink::{emit_all, NullSink};
use aic::coordinator::store::Store;
use aic::coordinator::stream::{run_streaming, StreamOptions};
use aic::energy::synth::SynthSpec;
use aic::exec::Policy;
use aic::util::bench::{black_box, Bench};
use std::alloc::{GlobalAlloc, Layout, System};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

// --- counting allocator: live bytes + high-water mark ----------------

struct PeakAlloc;

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

fn on_alloc(size: usize) {
    let live = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        on_alloc(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: PeakAlloc = PeakAlloc;

/// Run `f` once and return its peak live-byte delta over the baseline.
fn peak_of(f: impl FnOnce()) -> u64 {
    let baseline = LIVE.load(Ordering::SeqCst);
    PEAK.store(baseline, Ordering::SeqCst);
    f();
    PEAK.load(Ordering::SeqCst).saturating_sub(baseline)
}

fn mib(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

// --- the grid --------------------------------------------------------

fn grid() -> Scenario {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let seeds: Vec<u64> = (1..=if fast { 25 } else { 2500 }).collect();
    Scenario::new("campaign_stream", WorkloadSpec::Audio)
        .with_title("streaming-vs-batch campaign grid")
        .with_harvesters(vec![
            HarvesterSpec::Synth(SynthSpec::builtin_rf()),
            HarvesterSpec::Synth(SynthSpec::builtin_multi()),
        ])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_seeds(seeds)
        .with_horizon(120.0)
        .with_sample_period(30.0)
        .with_projection(Projection::Cells)
}

fn store_path() -> PathBuf {
    std::env::temp_dir().join(format!("aic_campaign_stream_{}.aic", std::process::id()))
}

fn batch_once(sc: &Scenario) {
    let cache = SupplyCache::new();
    let run = sc.run_cached(false, None, None, &cache);
    emit_all(&run.tables(), &mut NullSink).expect("null sink never fails");
}

fn stream_once(sc: &Scenario, store: Option<&mut Store>) {
    let cache = SupplyCache::new();
    let opts = StreamOptions::default();
    let report = run_streaming(sc, &opts, None, &cache, store, &mut NullSink)
        .expect("streaming sweep failed");
    black_box(report.ran + report.reused);
}

fn main() {
    let b = Bench::new("campaign_stream");
    let sc = grid();
    let cells = sc.plan().len();
    let path = store_path();

    // --- peak live heap, one run per leg (not timed) -----------------
    let peak_batch = peak_of(|| batch_once(&sc));
    let peak_stream = peak_of(|| stream_once(&sc, None));
    let _ = std::fs::remove_file(&path);
    let peak_store = peak_of(|| {
        let mut store = Store::open(&path).expect("open store");
        stream_once(&sc, Some(&mut store));
    });

    // --- wall time ---------------------------------------------------
    b.bench("batch_cells", || batch_once(&sc));
    b.bench("stream_cells", || stream_once(&sc, None));
    b.bench("stream_cells_store_cold", || {
        let _ = std::fs::remove_file(&path);
        let mut store = Store::open(&path).expect("open store");
        stream_once(&sc, Some(&mut store));
    });
    // Leave the store fully committed, then time the pure-resume replay:
    // every cell folds from its committed digest, nothing simulates.
    {
        let _ = std::fs::remove_file(&path);
        let mut store = Store::open(&path).expect("open store");
        stream_once(&sc, Some(&mut store));
    }
    b.bench("stream_cells_store_resume", || {
        let mut store = Store::open(&path).expect("open store");
        stream_once(&sc, Some(&mut store));
    });
    let _ = std::fs::remove_file(&path);

    b.report_table(
        &format!("peak live heap over a {cells}-cell grid"),
        &["leg", "peak MiB"],
        &[
            vec!["batch run_cached + tables".into(), mib(peak_batch)],
            vec!["streamed, no store".into(), mib(peak_stream)],
            vec!["streamed + store".into(), mib(peak_store)],
        ],
    );
    println!(
        "(batch/stream peak ratio: {:.1}x over {cells} cells)",
        peak_batch as f64 / peak_stream.max(1) as f64
    );
}
