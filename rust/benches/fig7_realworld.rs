//! Fig. 7 bench: real-world experiments — coherence of the classification
//! against a continuous execution on the same wrist, and throughput
//! normalised to it (two devices per volunteer, §5.3).
//!
//! Paper shape: coherence >= ~91 % for every approximate policy (SMART
//! above GREEDY); more than half of the continuous execution's
//! classifications are delivered, with GREEDY highest in throughput
//! (trend reversed vs coherence).

use aic::coordinator::scenario::builtin;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig7_realworld");
    // §5.3: six volunteers, ~56 h each; scaled-down horizon here.
    let sc = builtin("fig7", 42)
        .expect("fig7 scenario")
        .with_horizon(if fast { 1800.0 } else { 6.0 * 3600.0 })
        .with_seeds(if fast { vec![11, 12] } else { vec![11, 12, 13, 14, 15, 16] });
    let ctx = sc.har_context();

    let mut rows_out = Vec::new();
    b.bench("wrist_pair_campaigns", || {
        rows_out = sc.run_with(false, Some(&ctx), None).policy_rows();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .filter(|r| !matches!(r.policy, Policy::Continuous))
        .map(|r| {
            vec![
                r.policy.name(),
                format!("{:.1}%", 100.0 * r.coherence_vs_continuous),
                format!("{:.1}%", 100.0 * r.throughput_vs_continuous),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 7 — coherence and throughput vs continuous",
        &["policy", "coherence", "thrpt vs continuous"],
        &rows,
    );

    let get = |p: Policy| rows_out.iter().find(|r| r.policy == p).unwrap();
    let greedy = get(Policy::Greedy);
    let s80 = get(Policy::Smart { bound: 0.80 });
    println!(
        "shape: coherence high for approx ({:.0}%) [{}]",
        100.0 * greedy.coherence_vs_continuous,
        if greedy.coherence_vs_continuous > 0.70 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: smart coherence >= greedy [{}]",
        if s80.coherence_vs_continuous >= greedy.coherence_vs_continuous - 0.02 {
            "PASS"
        } else {
            "FAIL"
        }
    );
    println!(
        "shape: >1/3 of continuous results delivered ({:.0}%) [{}]",
        100.0 * greedy.throughput_vs_continuous,
        if greedy.throughput_vs_continuous > 0.33 { "PASS" } else { "FAIL" }
    );
}
