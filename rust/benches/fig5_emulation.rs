//! Fig. 5 bench: emulation experiments — classification accuracy (a) and
//! system throughput normalised to a continuous execution (b), for
//! GREEDY, SMART-60, SMART-80 and Chinchilla.
//!
//! Paper shape: Chinchilla matches the continuous accuracy ceiling but
//! loses most of the throughput; the approximate runtimes trade a
//! bounded accuracy loss (~11 pp worst case) for large throughput gains
//! (up to 7x Chinchilla); SMART sits above GREEDY in accuracy and below
//! in throughput, with the higher bound amplifying both effects.

use aic::coordinator::scenario::builtin;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig5_emulation");
    let mut sc = builtin("fig5", 42).expect("fig5 scenario");
    if fast {
        sc = sc.with_horizon(1800.0).with_seeds(vec![1, 2]);
    }
    // Full-fidelity training even in fast mode (historical bench setup);
    // train once outside the timed region.
    let ctx = sc.har_context();

    let mut rows_out = Vec::new();
    b.bench("policy_campaigns", || {
        rows_out = sc.run_with(false, Some(&ctx), None).policy_rows();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.policy.name(),
                format!("{:.1}%", 100.0 * r.accuracy),
                format!("{:.1}%", 100.0 * r.throughput_vs_continuous),
                format!("{:.1}", r.mean_features),
                format!("{:.1}%", 100.0 * r.state_energy_fraction),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 5 — accuracy and normalised throughput",
        &["policy", "accuracy", "thrpt vs continuous", "mean features", "state energy frac"],
        &rows,
    );

    let get = |p: Policy| rows_out.iter().find(|r| r.policy == p).unwrap();
    let greedy = get(Policy::Greedy);
    let chin = get(Policy::Chinchilla);
    let s80 = get(Policy::Smart { bound: 0.80 });
    println!(
        "shape: chinchilla best accuracy [{}]",
        if chin.accuracy >= greedy.accuracy - 0.02 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: greedy throughput >> chinchilla ({:.1}x) [{}]",
        greedy.throughput_vs_continuous / chin.throughput_vs_continuous.max(1e-9),
        if greedy.throughput_vs_continuous > 1.5 * chin.throughput_vs_continuous {
            "PASS"
        } else {
            "FAIL"
        }
    );
    println!(
        "shape: smart80 accuracy >= greedy [{}]",
        if s80.accuracy >= greedy.accuracy - 0.02 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: approx spends nothing on state [{}]",
        if greedy.state_energy_fraction == 0.0 { "PASS" } else { "FAIL" }
    );
    let alpaca = get(Policy::Alpaca);
    println!(
        "shape: alpaca precise like chinchilla [{}]",
        if alpaca.accuracy >= chin.accuracy - 0.02 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: alpaca state overhead below chinchilla ({:.1}% vs {:.1}%) [{}]",
        100.0 * alpaca.state_energy_fraction,
        100.0 * chin.state_energy_fraction,
        if alpaca.state_energy_fraction < chin.state_energy_fraction {
            "PASS"
        } else {
            "FAIL"
        }
    );
}
