//! Fig. 15 bench: distribution of the latency to produce the corner
//! output (power cycles), for an energy-rich (SOR) and an energy-poor,
//! highly dynamic (RF) trace.
//!
//! Paper shape: AIC is not shown (always same-cycle by design);
//! Chinchilla concludes within ~10 cycles under energy abundance (SOR)
//! and stretches over more cycles under RF.

use aic::coordinator::metrics::{latency_histogram, same_cycle_fraction};
use aic::coordinator::scenario::{builtin, HarvesterSpec, SweepRun};
use aic::energy::traces::TraceKind;
use aic::exec::Policy;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig15_latency_img");
    // The historical bench grid: SOR + RF only, no continuous baseline,
    // trace seed 3 (the old ImgRunSpec default).
    let sc = builtin("fig15", 3)
        .expect("fig15 scenario")
        .with_harvesters(vec![
            HarvesterSpec::Ambient(TraceKind::Sor),
            HarvesterSpec::Ambient(TraceKind::Rf),
        ])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla]);

    let mut run_out: Option<SweepRun> = None;
    b.bench("sor_rf_latency", || {
        run_out = Some(sc.run(fast));
    });
    let run = run_out.expect("bench ran at least once");
    let g = run.policy_index(Policy::Greedy).unwrap();
    let c = run.policy_index(Policy::Chinchilla).unwrap();
    let results: Vec<_> = [TraceKind::Sor, TraceKind::Rf]
        .iter()
        .enumerate()
        .map(|(hi, &trace)| {
            let aic_run = &run.img_campaigns()[run.cell_index(hi, 0, g, 0)];
            let chin = &run.img_campaigns()[run.cell_index(hi, 0, c, 0)];
            (trace, aic_run, chin)
        })
        .collect();

    let mut rows = Vec::new();
    for (trace, aic_run, chin) in &results {
        let h = latency_histogram(chin, 40);
        let mean = chin
            .emitted()
            .map(|r| r.latency_cycles as f64)
            .sum::<f64>()
            / chin.emitted().count().max(1) as f64;
        rows.push(vec![
            trace.name().to_string(),
            format!("{:.1}%", 100.0 * same_cycle_fraction(aic_run)),
            format!("{:.1}%", 100.0 * h.frac(0)),
            format!("{mean:.1}"),
        ]);
    }
    b.report_table(
        "Fig. 15 — latency per trace",
        &["trace", "AIC same-cycle", "Chinchilla same-cycle", "Chinchilla mean cycles"],
        &rows,
    );

    for (trace, aic_run, chin) in &results {
        println!(
            "shape: AIC same-cycle on {} [{}]",
            trace.name(),
            if same_cycle_fraction(aic_run) > 0.999 { "PASS" } else { "FAIL" }
        );
        let chin_mean = chin.emitted().map(|r| r.latency_cycles as f64).sum::<f64>()
            / chin.emitted().count().max(1) as f64;
        if *trace == TraceKind::Rf {
            println!(
                "shape: RF stretches Chinchilla (mean {:.1} cycles) [{}]",
                chin_mean,
                if chin_mean >= 1.0 { "PASS" } else { "FAIL" }
            );
        }
    }
    // SOR should conclude in fewer cycles than RF.
    let mean_of = |i: usize| -> f64 {
        let c = results[i].2;
        c.emitted().map(|r| r.latency_cycles as f64).sum::<f64>()
            / c.emitted().count().max(1) as f64
    };
    println!(
        "shape: abundance (SOR {:.1}) beats scarcity (RF {:.1}) [{}]",
        mean_of(0),
        mean_of(1),
        if mean_of(0) <= mean_of(1) { "PASS" } else { "FAIL" }
    );
}
