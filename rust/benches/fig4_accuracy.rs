//! Fig. 4 bench: expected vs measured accuracy as a function of the
//! number of features used for classification.
//!
//! Paper shape: both curves start at chance (16.6 %), rise rapidly over
//! the first features, flatten out, and top at ~88 %; the expected curve
//! (Eq. 7 analysis) stays close to the measured one throughout.

use aic::coordinator::scenario::builtin;
use aic::util::bench::Bench;

fn main() {
    let b = Bench::new("fig4_accuracy");
    let sc = builtin("fig4", 42).expect("fig4 scenario");
    // Train once outside the timed region (the curve is the deliverable).
    let ctx = sc.har_context();

    let mut rows_out = Vec::new();
    b.bench("expected_and_measured_curves", || {
        rows_out = sc.run_with(false, Some(&ctx), None).accuracy_rows().to_vec();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.p.to_string(),
                format!("{:.1}%", 100.0 * r.expected),
                format!("{:.1}%", 100.0 * r.measured),
                format!("{:+.1}pp", 100.0 * (r.expected - r.measured)),
            ]
        })
        .collect();
    b.report_table(
        "Fig. 4 — accuracy vs number of features",
        &["features", "expected", "measured", "delta"],
        &rows,
    );

    // Paper-shape checks (soft: print PASS/FAIL, never panic in benches).
    let last = rows_out.last().unwrap();
    let ceiling_ok = last.measured > 0.80 && last.measured < 0.97;
    let chance_start = rows_out[0].measured < 0.30;
    let tracks = rows_out.iter().all(|r| (r.expected - r.measured).abs() < 0.25);
    println!("shape: ceiling ~88% [{}]", if ceiling_ok { "PASS" } else { "FAIL" });
    println!("shape: starts at chance [{}]", if chance_start { "PASS" } else { "FAIL" });
    println!("shape: expected tracks measured [{}]", if tracks { "PASS" } else { "FAIL" });
}
