//! Fig. 12 bench: representative corner-detection outputs as a function
//! of the fraction of loop iterations not executed.
//!
//! Paper shape: for the simple picture, more than half of the iterations
//! may be skipped with an equivalent output; for complex pictures the
//! observation holds up to ~42 %; beyond that the corner count drops and
//! spurious detections appear.

use aic::coordinator::scenario::{builtin, WorkloadSpec};
use aic::imgproc::images::Picture;
use aic::util::bench::Bench;

fn main() {
    let fast = std::env::var("AIC_BENCH_FAST").is_ok();
    let b = Bench::new("fig12_perforation");
    // The bench sweeps a denser skip grid than the figure scenario.
    let sc = builtin("fig12", 42).expect("fig12 scenario").with_workload(
        WorkloadSpec::Perforation {
            size: if fast { 96 } else { aic::imgproc::images::EVAL_SIZE },
            skips: vec![0.0, 0.1, 0.2, 0.3, 0.42, 0.5, 0.6, 0.7, 0.85],
        },
    );

    let mut rows_out = Vec::new();
    b.bench("perforation_sweep", || {
        rows_out = sc.run(false).perforation_rows().to_vec();
    });

    let rows: Vec<Vec<String>> = rows_out
        .iter()
        .map(|r| {
            vec![
                r.picture.name().to_string(),
                format!("{:.0}%", 100.0 * r.skip_fraction),
                r.corners.to_string(),
                r.reference_corners.to_string(),
                if r.equivalent { "yes".into() } else { "no".into() },
            ]
        })
        .collect();
    b.report_table(
        "Fig. 12 — corners vs skipped iterations",
        &["picture", "skipped", "corners", "reference", "equivalent"],
        &rows,
    );

    // Shape: the simple picture survives heavier perforation than the
    // cluttered one; moderate perforation (<= 42%) keeps close counts.
    let max_equivalent_skip = |p: Picture| -> f64 {
        rows_out
            .iter()
            .filter(|r| r.picture == p && r.equivalent)
            .map(|r| r.skip_fraction)
            .fold(0.0, f64::max)
    };
    let simple = max_equivalent_skip(Picture::Checker);
    let complex = max_equivalent_skip(Picture::Cluttered);
    println!(
        "shape: simple survives >= 42% skipping (got {:.0}%) [{}]",
        100.0 * simple,
        if simple >= 0.42 { "PASS" } else { "FAIL" }
    );
    println!(
        "shape: simple tolerates >= complex [{}]",
        if simple >= complex { "PASS" } else { "FAIL" }
    );
    let moderate_close = rows_out
        .iter()
        .filter(|r| r.skip_fraction <= 0.3)
        .all(|r| (r.corners as f64) >= 0.7 * r.reference_corners as f64);
    println!(
        "shape: <=30% skipping keeps >=70% of corners [{}]",
        if moderate_close { "PASS" } else { "FAIL" }
    );
}
