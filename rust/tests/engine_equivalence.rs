//! Golden-trajectory equivalence: the analytic event-driven engine must
//! reproduce the fixed-step reference engine (the original integrator,
//! preserved behind `EngineKind::FixedStep`).
//!
//! Three layers of evidence:
//!
//! 1. **Exact-on-constants properties** — with a constant harvester the
//!    reference integrator computes the same piecewise-linear energy
//!    trajectory as the closed forms, so boot times agree to one stride,
//!    ledger totals to float noise.
//! 2. **Fine-grained-limit properties** — on randomized replay traces
//!    the reference with `charge_dt → 0` converges to the exact integral
//!    the analytic engine computes; a 1 ms reference must agree closely.
//! 3. **Campaign goldens** — full GREEDY and Chinchilla campaigns on all
//!    five ambient traces plus the kinetic HAR harvester, compared at
//!    the paper's `charge_dt = 0.02`: per-round outcomes, power-cycle
//!    counts and ledger totals within tolerance of the discretisation
//!    error the reference itself carries.

use aic::energy::harvester::{kinetic_power_trace, Harvester, KineticConfig};
use aic::energy::mcu::OpCost;
use aic::energy::traces::{generate, PowerTrace, TraceKind};
use aic::exec::approx::{run as run_approx, ApproxConfig};
use aic::exec::chinchilla::{run as run_chinchilla, ChinchillaConfig};
use aic::exec::engine::{Engine, EngineConfig, EngineKind, Ledger};
use aic::exec::program::SyntheticProgram;
use aic::util::rng::Rng;
use aic::util::testkit::{assert_campaigns_close, property, Gen};
use std::f64::consts::PI;

/// An (analytic, fixed-step reference) engine pair on the same device.
fn engines(h: &Harvester, horizon: f64, v0: f64, ref_dt: f64) -> (Engine, Engine) {
    let mut ac = EngineConfig::paper_default(horizon);
    ac.kind = EngineKind::Analytic;
    ac.initial_voltage = v0;
    let mut rc = EngineConfig::reference(horizon);
    rc.initial_voltage = v0;
    rc.charge_dt = ref_dt;
    (Engine::new(ac, h.clone()), Engine::new(rc, h.clone()))
}

#[test]
fn constant_harvester_boot_times_agree() {
    property("analytic boot vs reference", 48, |g: &mut Gen| {
        let power = g.f64_in(0.2e-3..3e-3);
        let v0 = g.f64_in(0.0..2.9);
        let dt = 1e-3;
        let (mut a, mut r) = engines(&Harvester::Constant(power), 1e5, v0, dt);
        assert!(a.charge_until_boot(), "analytic never booted at {power} W");
        assert!(r.charge_until_boot(), "reference never booted at {power} W");
        assert!(
            (a.now - r.now).abs() <= dt + 1e-9,
            "power={power} v0={v0}: boot at {} (analytic) vs {} (reference)",
            a.now,
            r.now
        );
        assert_eq!(a.cycles, r.cycles);
        // Reference overshoots V_on by at most one stride of charge.
        assert!(
            (a.cap.energy() - r.cap.energy()).abs() <= power * dt + 1e-12,
            "boot energy {} vs {}",
            a.cap.energy(),
            r.cap.energy()
        );
    });
}

#[test]
fn constant_harvester_sleep_brownout_times_agree() {
    property("analytic sleep vs reference", 12, |g: &mut Gen| {
        // Output power ~0 (below the booster's quiescent draw): the
        // V_off crossing is a pure linear drain with an exact answer.
        let power = g.f64_in(0.0..1.5e-6);
        let v0 = g.f64_in(2.2..3.4);
        let dt = 5e-3;
        let (mut a, mut r) = engines(&Harvester::Constant(power), 5e7, v0, dt);
        assert!(!a.sleep(4e6), "analytic survived an unsurvivable sleep");
        assert!(!r.sleep(4e6), "reference survived an unsurvivable sleep");
        // Reference detects the crossing within one wide (5×) stride.
        assert!(
            (a.now - r.now).abs() <= 5.0 * dt + 1e-6,
            "power={power} v0={v0}: died at {} (analytic) vs {} (reference)",
            a.now,
            r.now
        );
        assert_eq!(a.failures, 1);
        assert_eq!(r.failures, 1);
    });
}

#[test]
fn constant_harvester_op_sequences_match_exactly() {
    property("analytic ops vs reference", 24, |g: &mut Gen| {
        let power = g.f64_in(0.0..2e-3);
        let v0 = g.f64_in(2.4..3.5);
        let (mut a, mut r) = engines(&Harvester::Constant(power), 1e9, v0, 0.02);
        for i in 0..25 {
            let cost = OpCost {
                cycles: 1_000 + g.usize_in(0..=400_000) as u64,
                fram_writes: g.usize_in(0..=50) as u64,
                ble_bytes: if g.bool() { 20 } else { 0 },
                ..Default::default()
            };
            let ledger = if g.bool() { Ledger::App } else { Ledger::State };
            let oa = a.run_op(&cost, ledger);
            let or = r.run_op(&cost, ledger);
            assert_eq!(oa, or, "op {i} diverged (power={power} v0={v0})");
        }
        let rel = |x: f64, y: f64| (x - y).abs() / x.abs().max(y.abs()).max(1e-12);
        assert!((a.now - r.now).abs() < 1e-6, "time {} vs {}", a.now, r.now);
        assert!(
            rel(a.app_energy, r.app_energy) < 1e-9,
            "app ledger {} vs {}",
            a.app_energy,
            r.app_energy
        );
        assert!(
            rel(a.state_energy, r.state_energy) < 1e-9,
            "state ledger {} vs {}",
            a.state_energy,
            r.state_energy
        );
        assert!(
            (a.cap.energy() - r.cap.energy()).abs() < 1e-9,
            "buffer {} vs {}",
            a.cap.energy(),
            r.cap.energy()
        );
        assert_eq!(a.failures, r.failures);
    });
}

/// Random wrapping replay trace: zero-biased so RF-like off runs occur.
fn random_trace(g: &mut Gen) -> PowerTrace {
    let n = g.usize_in(10..=120).max(2);
    let dt = g.f64_in(0.05..0.4).max(0.01);
    let samples: Vec<f64> = (0..n)
        .map(|_| if g.bool() { 0.0 } else { g.f64_in(0.0..2.5e-3).max(0.0) })
        .collect();
    PowerTrace { dt, samples }
}

#[test]
fn random_replay_boot_matches_fine_grained_reference() {
    property("analytic replay boot", 20, |g: &mut Gen| {
        let horizon = 2e4;
        let h = Harvester::Replay(random_trace(g));
        // A 1 ms reference approaches the exact integral the analytic
        // engine computes in closed form.
        let (mut a, mut r) = engines(&h, horizon, 1.0, 1e-3);
        let ab = a.charge_until_boot();
        let rb = r.charge_until_boot();
        match (ab, rb) {
            (true, true) => {
                if r.now < 0.95 * horizon {
                    assert!(
                        (a.now - r.now).abs() <= 0.02 * r.now.max(1.0) + 0.1,
                        "boot at {} (analytic) vs {} (reference)",
                        a.now,
                        r.now
                    );
                }
            }
            (false, false) => {}
            // A disagreement is only legitimate right at the horizon.
            (true, false) => assert!(
                a.now > 0.9 * horizon,
                "analytic booted at {} but the reference never did",
                a.now
            ),
            (false, true) => assert!(
                r.now > 0.9 * horizon,
                "reference booted at {} but the analytic engine never did",
                r.now
            ),
        }
    });
}

#[test]
fn random_replay_sleep_tracks_fine_grained_reference() {
    property("analytic replay sleep", 16, |g: &mut Gen| {
        let h = Harvester::Replay(random_trace(g));
        let v0 = g.f64_in(2.6..3.3);
        let (mut a, mut r) = engines(&h, 1e6, v0, 1e-3);
        // 40 s of sleep drains ~56 µJ against a ≥2.6 V buffer: both
        // stay alive, so this isolates the energy integral (including
        // the rail clamp) from brown-out edge effects.
        assert!(a.sleep(40.0));
        assert!(r.sleep(40.0));
        assert!((a.now - r.now).abs() < 1e-6, "time {} vs {}", a.now, r.now);
        assert!(
            (a.cap.energy() - r.cap.energy()).abs() < 2e-5,
            "v0={v0}: buffer {} vs {} after sleep",
            a.cap.energy(),
            r.cap.energy()
        );
    });
}

// ---------------------------------------------------------------------
// Campaign goldens: all five ambient traces + the kinetic harvester.
// ---------------------------------------------------------------------

fn synthetic_walking(secs: f64, fs: f64) -> Vec<f64> {
    let mut rng = Rng::new(77);
    (0..(secs * fs) as usize)
        .map(|i| {
            let t = i as f64 / fs;
            6.0 * (2.0 * PI * 2.0 * t).sin() + 0.4 * rng.gaussian()
        })
        .collect()
}

/// The six supplies the paper campaigns on: RF/SOM/SIM/SOR/SIR replay
/// traces plus the kinetic wrist harvester.
fn supplies() -> Vec<(String, Harvester)> {
    let mut out: Vec<(String, Harvester)> = TraceKind::ALL
        .iter()
        .map(|&k| (k.name().to_string(), Harvester::Replay(generate(k, 600.0, 0.01, 11))))
        .collect();
    let accel = synthetic_walking(120.0, 50.0);
    out.push((
        "kinetic".to_string(),
        Harvester::Replay(kinetic_power_trace(&accel, 50.0, &KineticConfig::default())),
    ));
    out
}

// `assert_campaigns_close` moved to `util::testkit` so the synthetic-
// environment suite (`tests/synth_properties.rs`) gates its supplies
// through the exact same tolerance contract.

#[test]
fn golden_greedy_campaigns_match_reference_on_all_supplies() {
    for (name, h) in supplies() {
        let (mut a, mut r) = engines(&h, 1800.0, 3.0, 0.02);
        let mut pa = SyntheticProgram::new(1000, 140, 300_000);
        let mut pr = SyntheticProgram::new(1000, 140, 300_000);
        let ca = run_approx(&mut pa, &mut a, &ApproxConfig::greedy(60.0));
        let cr = run_approx(&mut pr, &mut r, &ApproxConfig::greedy(60.0));
        assert!(
            cr.emitted().count() > 0,
            "{name}: reference GREEDY campaign emitted nothing"
        );
        assert_campaigns_close(&name, &ca, &cr);
    }
}

#[test]
fn golden_audio_campaigns_match_reference_on_all_supplies() {
    // The third workload through the same gate: GREEDY anytime audio on
    // all five ambient traces plus the kinetic harvester, analytic vs
    // fixed-step reference.
    use aic::audio::app::{AudioProgram, AudioSource};
    use aic::audio::detector::SpectralDetector;
    use aic::audio::stream::AudioScript;
    let program = || {
        AudioProgram::new(
            SpectralDetector::paper_default(),
            AudioSource::Script(AudioScript::generate(1800.0, 7)),
        )
    };
    for (name, h) in supplies() {
        let (mut a, mut r) = engines(&h, 1800.0, 3.0, 0.02);
        let mut pa = program();
        let mut pr = program();
        let ca = run_approx(&mut pa, &mut a, &ApproxConfig::greedy(30.0));
        let cr = run_approx(&mut pr, &mut r, &ApproxConfig::greedy(30.0));
        assert!(
            cr.emitted().count() > 0,
            "{name}: reference audio campaign emitted nothing"
        );
        assert_campaigns_close(&name, &ca, &cr);
    }
}

#[test]
fn golden_chinchilla_campaigns_match_reference_on_all_supplies() {
    for (name, h) in supplies() {
        let (mut a, mut r) = engines(&h, 1800.0, 3.0, 0.02);
        let mut pa = SyntheticProgram::new(1000, 60, 300_000);
        let mut pr = SyntheticProgram::new(1000, 60, 300_000);
        let ca = run_chinchilla(&mut pa, &mut a, &ChinchillaConfig::default());
        let cr = run_chinchilla(&mut pr, &mut r, &ChinchillaConfig::default());
        assert_campaigns_close(&name, &ca, &cr);
        // Chinchilla is precise under both integrators.
        for c in [&ca, &cr] {
            for round in c.emitted() {
                assert_eq!(round.output, Some(60), "{name}: truncated emission");
            }
        }
    }
}
