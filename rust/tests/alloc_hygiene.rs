//! Steady-state allocation hygiene.
//!
//! The per-round hot paths — the round driver, audio window assembly,
//! and the Harris row kernel — are required to stop allocating once
//! their reusable buffers have warmed. A counting global allocator
//! measures exactly that: warm a program up, then assert further
//! rounds perform zero heap allocations (driver: an allocation count
//! independent of the round count).
//!
//! Deliberately a single `#[test]` in its own integration binary: the
//! allocation counter is process-global, so concurrent tests in the
//! same binary would race it.

use aic::audio::app::{AudioProgram, AudioSource};
use aic::audio::detector::SpectralDetector;
use aic::audio::stream::AudioScript;
use aic::coordinator::experiment::SupplyCache;
use aic::coordinator::scenario::{HarvesterSpec, Projection, Scenario, WorkloadSpec};
use aic::coordinator::sink::NullSink;
use aic::coordinator::stream::{run_streaming, StreamOptions};
use aic::energy::harvester::Harvester;
use aic::energy::traces::TraceKind;
use aic::exec::engine::{Engine, EngineConfig};
use aic::exec::program::{StepProgram, SyntheticProgram};
use aic::exec::runtime::RuntimeSpec;
use aic::exec::Policy;
use aic::imgproc::app::CornerProgram;
use aic::imgproc::harris::HarrisConfig;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Currently-live heap bytes.
static LIVE: AtomicU64 = AtomicU64::new(0);
/// High-water mark of `LIVE` since the last `reset_peak`.
static PEAK: AtomicU64 = AtomicU64::new(0);

fn on_alloc(size: usize) {
    ALLOCS.fetch_add(1, Ordering::Relaxed);
    let live = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        let live = LIVE.fetch_add(new_size as u64, Ordering::Relaxed) + new_size as u64;
        PEAK.fetch_max(live, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn live_bytes() -> u64 {
    LIVE.load(Ordering::SeqCst)
}

/// Restart peak tracking from the current live footprint and return
/// that baseline.
fn reset_peak() -> u64 {
    let live = live_bytes();
    PEAK.store(live, Ordering::SeqCst);
    live
}

fn peak_bytes() -> u64 {
    PEAK.load(Ordering::SeqCst)
}

/// One full audio round: acquire, run every refinement step, classify.
fn audio_round(prog: &mut AudioProgram, t: f64) -> usize {
    assert!(prog.load_next(t));
    for j in 0..prog.num_steps() {
        prog.execute_step(j);
    }
    let out = prog.output();
    prog.reset_round();
    out.predicted
}

/// One Harris round through the step loop (output/detect excluded: the
/// emitted corner list is a fresh per-round allocation by design).
fn harris_round(prog: &mut CornerProgram, t: f64) {
    assert!(prog.load_next(t));
    for j in 0..prog.num_steps() {
        prog.execute_step(j);
    }
    prog.reset_round();
}

#[test]
fn steady_state_round_loops_do_not_allocate() {
    // --- Audio: window assembly + Goertzel probes. -------------------
    let script = AudioScript::generate(3600.0, 11);
    let mut audio = AudioProgram::new(SpectralDetector::paper_default(), AudioSource::Script(script));
    // Warm-up: first rounds grow the window/powers buffers.
    for t in [0.0, 30.0] {
        audio_round(&mut audio, t);
    }
    let before = allocs();
    let mut sink = 0usize;
    for t in [60.0, 90.0, 120.0, 150.0, 180.0] {
        sink += audio_round(&mut audio, t);
    }
    let audio_delta = allocs() - before;
    assert_eq!(
        audio_delta, 0,
        "audio steady-state rounds allocated {audio_delta} times (sink {sink})"
    );

    // --- Imaging: render, gradients and the response-row kernel. ----
    let mut harris = CornerProgram::new(HarrisConfig::default(), 32, &[3, 4], 2);
    for t in [0.0, 30.0, 60.0] {
        harris_round(&mut harris, t);
    }
    let before = allocs();
    for t in [90.0, 120.0, 150.0] {
        harris_round(&mut harris, t);
    }
    let harris_delta = allocs() - before;
    assert_eq!(
        harris_delta, 0,
        "harris steady-state rounds allocated {harris_delta} times"
    );

    // --- Round driver: allocation count independent of round count. --
    // The rounds vector is reserved once up front, and the GREEDY round
    // path is allocation-free, so doubling the horizon (≈ doubling the
    // number of rounds) must not change how many allocations one
    // campaign performs.
    let spec = RuntimeSpec::new(60.0);
    let rt = Policy::Greedy.runtime::<SyntheticProgram>(&spec);
    let mut run = |horizon: f64| -> (u64, usize) {
        let mut program = SyntheticProgram::new(10_000, 5, 5_000);
        let mut engine =
            Engine::new(EngineConfig::paper_default(horizon), Harvester::Constant(2e-3));
        let before = allocs();
        let campaign = rt.run(&mut program, &mut engine);
        let delta = allocs() - before;
        (delta, campaign.rounds.len())
    };
    let (short_allocs, short_rounds) = run(3600.0);
    let (long_allocs, long_rounds) = run(7200.0);
    assert!(
        long_rounds > short_rounds,
        "horizon doubling must add rounds ({short_rounds} -> {long_rounds})"
    );
    assert_eq!(
        short_allocs, long_allocs,
        "driver allocations must not scale with rounds \
         ({short_rounds} rounds: {short_allocs} allocs, \
          {long_rounds} rounds: {long_allocs} allocs)"
    );

    // --- Streaming sweeps: peak memory independent of cell count. ----
    // The batch path retains every campaign of the grid (MemorySink/
    // SweepRun keep O(cells)); the streaming path must not. A 9×-larger
    // seed axis may not raise the sweep's peak live-byte footprint
    // beyond per-cell jitter, and the run must hand its memory back.
    let sweep = |seeds: Vec<u64>| -> (u64, u64) {
        let sc = Scenario::new("alloc_stream", WorkloadSpec::Audio)
            .with_harvesters(vec![HarvesterSpec::Ambient(TraceKind::Rf)])
            .with_policies(vec![Policy::Continuous])
            .with_seeds(seeds)
            .with_horizon(3600.0)
            .with_sample_period(30.0)
            .with_projection(Projection::Cells);
        let opts = StreamOptions {
            workers: Some(1),
            chunk: 2,
            ..StreamOptions::default()
        };
        // A disabled cache holds nothing; the supply dies with its cell.
        let cache = SupplyCache::disabled();
        let mut sink = NullSink;
        let baseline = reset_peak();
        let report =
            run_streaming(&sc, &opts, None, &cache, None, &mut sink).expect("stream sweep");
        assert_eq!(report.ran, report.cells);
        let peak = peak_bytes() - baseline;
        let retained = live_bytes().saturating_sub(baseline);
        (peak, retained)
    };
    // Warm-up: process-global one-time state (trace tables, etc.) must
    // not be billed to either measured run.
    let _ = sweep(vec![1, 2]);
    let (small_peak, _) = sweep((1..=4).collect());
    let (large_peak, large_retained) = sweep((1..=36).collect());
    let slack = 256 * 1024;
    assert!(
        large_peak <= small_peak + slack,
        "streaming peak must not scale with cell count \
         (4 cells: {small_peak} B, 36 cells: {large_peak} B)"
    );
    assert!(
        large_retained < 64 * 1024,
        "streamed sweep retained {large_retained} B after finishing"
    );
}
