//! End-to-end HAR integration: corpus → training → campaigns under every
//! policy, checking the paper's qualitative relations hold on a small
//! but non-trivial configuration.

use aic::coordinator::experiment::{run_har_policy, HarContext, HarRunSpec};
use aic::coordinator::scenario::{accuracy_rows, har_policies, PolicyRow, Scenario, WorkloadSpec};
use aic::coordinator::metrics::{har_accuracy, same_cycle_fraction};
use aic::exec::Policy;
use aic::har::dataset::CorpusSpec;

fn small_ctx() -> HarContext {
    HarContext::build_with(
        &CorpusSpec {
            train_volunteers: 4,
            test_volunteers: 2,
            windows_per_volunteer_per_class: 10,
        },
        404,
    )
}

/// The scenario-driven equivalent of the retired
/// `har_policy_comparison`: every §5 policy on the given volunteers.
fn comparison_rows(ctx: &HarContext, spec: &HarRunSpec, volunteers: Vec<u64>) -> Vec<PolicyRow> {
    Scenario::new("t", WorkloadSpec::Har)
        .with_policies(har_policies())
        .with_horizon(spec.horizon)
        .with_sample_period(spec.sample_period)
        .with_seeds(volunteers)
        .run_with(false, Some(ctx), None)
        .policy_rows()
}

#[test]
fn training_reaches_a_sane_ceiling() {
    let ctx = small_ctx();
    assert!(
        (0.6..=1.0).contains(&ctx.full_accuracy),
        "ceiling {} out of range",
        ctx.full_accuracy
    );
}

#[test]
fn fig4_expected_tracks_measured() {
    let ctx = small_ctx();
    let ps = [0usize, 20, 60, 100, 140];
    let rows = accuracy_rows(&ctx, &ps);
    // Both curves end at the ceiling and start near chance.
    assert!(rows[0].measured < 0.4);
    assert!((rows[4].measured - ctx.full_accuracy).abs() < 1e-9);
    for r in &rows {
        assert!(
            (r.expected - r.measured).abs() < 0.30,
            "p={}: expected {} vs measured {}",
            r.p,
            r.expected,
            r.measured
        );
    }
}

#[test]
fn greedy_campaign_single_cycle_and_accurate_enough() {
    let ctx = small_ctx();
    let spec = HarRunSpec { horizon: 3600.0, sample_period: 60.0, script_seed: 5 };
    let c = run_har_policy(&ctx, &spec, Policy::Greedy);
    assert!(c.emitted().count() >= 5, "too few results");
    assert!((same_cycle_fraction(&c) - 1.0).abs() < 1e-9);
    assert_eq!(c.state_energy, 0.0);
    // Accuracy above chance by a wide margin.
    assert!(har_accuracy(&c) > 0.35, "accuracy {}", har_accuracy(&c));
}

#[test]
fn policy_relations_match_paper() {
    let ctx = small_ctx();
    let spec = HarRunSpec { horizon: 2.0 * 3600.0, ..Default::default() };
    let rows = comparison_rows(&ctx, &spec, vec![3, 4]);
    let get = |p: Policy| rows.iter().find(|r| r.policy == p).unwrap();
    let cont = get(Policy::Continuous);
    let chin = get(Policy::Chinchilla);
    let greedy = get(Policy::Greedy);

    // Continuous is the throughput ceiling.
    assert!((cont.throughput_vs_continuous - 1.0).abs() < 1e-9);
    assert!(greedy.throughput_vs_continuous <= 1.0 + 1e-9);
    // The paper's central claim: approx beats Chinchilla in throughput.
    assert!(
        greedy.throughput_vs_continuous > chin.throughput_vs_continuous,
        "greedy {} <= chinchilla {}",
        greedy.throughput_vs_continuous,
        chin.throughput_vs_continuous
    );
    // Chinchilla processes every feature.
    assert!((chin.mean_features - 140.0).abs() < 1e-9);
    // GREEDY truncates.
    assert!(greedy.mean_features < 139.0);
    // Approx policies never touch the state ledger.
    assert_eq!(greedy.state_energy_fraction, 0.0);
    assert!(chin.state_energy_fraction > 0.0);
}

#[test]
fn smart_bound_orders_accuracy_and_throughput() {
    let ctx = small_ctx();
    let spec = HarRunSpec { horizon: 2.0 * 3600.0, ..Default::default() };
    let rows = comparison_rows(&ctx, &spec, vec![7, 8]);
    let get = |p: Policy| rows.iter().find(|r| r.policy == p).unwrap();
    let s60 = get(Policy::Smart { bound: 0.60 });
    let s80 = get(Policy::Smart { bound: 0.80 });
    // Higher bound -> no more throughput (it drops samples instead).
    assert!(
        s80.throughput_vs_continuous <= s60.throughput_vs_continuous + 0.05,
        "smart80 {} should not out-throughput smart60 {}",
        s80.throughput_vs_continuous,
        s60.throughput_vs_continuous
    );
}

#[test]
fn identical_seeds_reproduce_campaigns_exactly() {
    let ctx = small_ctx();
    let spec = HarRunSpec { horizon: 1200.0, sample_period: 60.0, script_seed: 9 };
    let a = run_har_policy(&ctx, &spec, Policy::Greedy);
    let b = run_har_policy(&ctx, &spec, Policy::Greedy);
    assert_eq!(a.rounds.len(), b.rounds.len());
    assert_eq!(a.power_cycles, b.power_cycles);
    assert_eq!(a.app_energy, b.app_energy);
    for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
        assert_eq!(ra.emitted_at, rb.emitted_at);
        assert_eq!(ra.steps_executed, rb.steps_executed);
    }
}
