//! Cross-runtime invariants: every `Policy` is driven over the same
//! `SyntheticProgram` through the `Runtime` trait — and over the audio
//! workload's `AudioProgram` — and the paper's structural guarantees are
//! asserted uniformly, plus fleet determinism across worker-pool sizes
//! for both the HAR and audio workloads.

use aic::audio::app::{self as audio_app, AudioOutput, AudioProgram, AudioSource};
use aic::audio::detector::SpectralDetector;
use aic::audio::stream::AudioScript;
use aic::audio::NUM_PROBES;
use aic::coordinator::experiment::{
    run_audio_policy, run_har_policy, test_context, AudioRunSpec, HarRunSpec, SupplyCache,
};
use aic::coordinator::fleet::run_fleet;
use aic::coordinator::scenario::{DeviceSpec, HarvesterSpec, Scenario, WorkloadSpec};
use aic::energy::synth::SynthSpec;
use aic::energy::estimator::{EnergyProfile, SmartTable};
use aic::energy::harvester::Harvester;
use aic::energy::mcu::{McuModel, OpCost};
use aic::energy::traces::TraceKind;
use aic::exec::engine::{Engine, EngineConfig};
use aic::exec::program::SyntheticProgram;
#[allow(unused_imports)]
use aic::exec::Runtime;
use aic::exec::{Campaign, Policy, RuntimeSpec};

const STEPS: usize = 60;
const CYCLES_PER_STEP: u64 = 200_000;
const INPUTS: u64 = 50;
const HORIZON: f64 = 2.0 * 3600.0;

fn all_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Alpaca,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
    ]
}

/// A SMART table for the synthetic program: linear accuracy from chance
/// to 0.9 over the step count.
fn synthetic_table() -> SmartTable {
    let mcu = McuModel::paper_default();
    let costs: Vec<OpCost> = (0..STEPS).map(|_| OpCost::cycles(CYCLES_PER_STEP)).collect();
    let profile = EnergyProfile::from_costs(&mcu, &costs);
    let acc: Vec<f64> = (0..=STEPS)
        .map(|p| 1.0 / 6.0 + (0.9 - 1.0 / 6.0) * p as f64 / STEPS as f64)
        .collect();
    let emit = mcu.energy(&OpCost { cycles: 500, ble_bytes: 1, ..Default::default() });
    SmartTable::new(acc, &profile, emit)
}

fn run_policy(policy: Policy, power: f64) -> Campaign<usize> {
    let mut program = SyntheticProgram::new(INPUTS, STEPS, CYCLES_PER_STEP);
    let mut engine = match policy {
        Policy::Continuous => Engine::powered(McuModel::paper_default(), HORIZON),
        _ => Engine::new(EngineConfig::paper_default(HORIZON), Harvester::Constant(power)),
    };
    let mut spec = RuntimeSpec::new(60.0);
    if let Policy::Smart { .. } = policy {
        spec = spec.with_smart_table(synthetic_table());
    }
    policy.runtime::<SyntheticProgram>(&spec).run(&mut program, &mut engine)
}

#[test]
fn emitted_never_exceeds_loaded_samples() {
    for policy in all_policies() {
        for power in [0.3e-3, 1.5e-3] {
            let c = run_policy(policy, power);
            let emitted = c.emitted().count();
            assert!(
                emitted <= c.rounds.len(),
                "{}: emitted {} > rounds {}",
                policy.name(),
                emitted,
                c.rounds.len()
            );
            assert!(
                c.rounds.len() as u64 <= INPUTS,
                "{}: {} rounds for {} inputs",
                policy.name(),
                c.rounds.len(),
                INPUTS
            );
        }
    }
}

#[test]
fn ledgers_are_non_negative_everywhere() {
    for policy in all_policies() {
        let c = run_policy(policy, 0.8e-3);
        assert!(c.app_energy >= 0.0, "{}", policy.name());
        assert!(c.state_energy >= 0.0, "{}", policy.name());
        assert!(
            c.app_energy > 0.0,
            "{}: campaign did no useful work at all",
            policy.name()
        );
    }
}

#[test]
fn stateless_policies_never_touch_the_state_ledger() {
    for policy in [
        Policy::Continuous,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
    ] {
        for power in [0.3e-3, 1.5e-3] {
            let c = run_policy(policy, power);
            assert_eq!(
                c.state_energy,
                0.0,
                "{}: managed persistent state",
                policy.name()
            );
        }
    }
}

#[test]
fn precise_policies_always_emit_full_precision() {
    // 60 steps × 200k cycles ≈ 3.7 mJ: a few power cycles per sample at
    // the weak setting, none at the strong one — precision must hold in
    // both regimes.
    for policy in [Policy::Chinchilla, Policy::Alpaca, Policy::Continuous] {
        for power in [0.4e-3, 2e-3] {
            let c = run_policy(policy, power);
            assert!(c.emitted().count() > 0, "{}: nothing emitted", policy.name());
            for r in c.emitted() {
                assert_eq!(
                    r.output,
                    Some(STEPS),
                    "{}: emitted a truncated result",
                    policy.name()
                );
            }
        }
    }
}

#[test]
fn approximate_policies_emit_within_the_acquisition_cycle() {
    for policy in [Policy::Greedy, Policy::Smart { bound: 0.60 }] {
        let c = run_policy(policy, 0.5e-3);
        for r in c.emitted() {
            assert_eq!(r.latency_cycles, 0, "{}", policy.name());
        }
    }
}

/// The audio twin of [`run_policy`]: every policy over the same seeded
/// event script on a constant supply.
fn run_audio(policy: Policy, power: f64) -> Campaign<AudioOutput> {
    let mut program = AudioProgram::new(
        SpectralDetector::paper_default(),
        AudioSource::Script(AudioScript::generate(HORIZON, 3)),
    );
    let mut engine = match policy {
        Policy::Continuous => Engine::powered(McuModel::paper_default(), HORIZON),
        _ => Engine::new(EngineConfig::paper_default(HORIZON), Harvester::Constant(power)),
    };
    let mut spec = RuntimeSpec::new(30.0);
    if let Policy::Smart { .. } = policy {
        spec = spec.with_smart_table(audio_app::smart_table(
            &SpectralDetector::paper_default(),
            &McuModel::paper_default(),
        ));
    }
    policy.runtime::<AudioProgram>(&spec).run(&mut program, &mut engine)
}

#[test]
fn audio_invariants_hold_across_every_policy() {
    for policy in all_policies() {
        for power in [0.3e-3, 1.5e-3] {
            let c = run_audio(policy, power);
            assert!(
                c.emitted().count() <= c.rounds.len(),
                "{}: emitted more than acquired",
                policy.name()
            );
            assert!(c.app_energy > 0.0, "{}: no useful work", policy.name());
            assert!(c.state_energy >= 0.0, "{}", policy.name());
        }
    }
}

#[test]
fn audio_precise_policies_emit_full_resolution() {
    for policy in [Policy::Chinchilla, Policy::Alpaca, Policy::Continuous] {
        let c = run_audio(policy, 0.8e-3);
        assert!(c.emitted().count() > 0, "{}: nothing emitted", policy.name());
        for r in c.emitted() {
            let out = r.output.as_ref().expect("emitted rounds carry output");
            assert_eq!(
                out.probes_used,
                NUM_PROBES,
                "{}: emitted a truncated spectrum",
                policy.name()
            );
            assert_eq!(out.predicted, out.truth, "{}: full resolution is exact", policy.name());
        }
    }
}

#[test]
fn audio_approximate_policies_stay_stateless_and_same_cycle() {
    for policy in [Policy::Greedy, Policy::Smart { bound: 0.60 }] {
        let c = run_audio(policy, 0.5e-3);
        assert_eq!(c.state_energy, 0.0, "{}: managed persistent state", policy.name());
        for r in c.emitted() {
            assert_eq!(r.latency_cycles, 0, "{}", policy.name());
        }
    }
}

#[test]
fn audio_fleet_is_deterministic_across_pool_sizes() {
    // The any-AIC_WORKERS determinism gate, extended to the third
    // workload: (policy × seed) audio cells on an ambient supply.
    let spec = AudioRunSpec { horizon: 900.0, ..Default::default() };
    let jobs: Vec<(Policy, u64)> = [Policy::Greedy, Policy::Chinchilla]
        .iter()
        .flat_map(|&p| [1u64, 2u64].map(|s| (p, s)))
        .collect();
    let run_job = |&(p, s): &(Policy, u64)| {
        run_audio_policy(
            &AudioRunSpec { stream_seed: s, ..spec.clone() },
            TraceKind::Som,
            p,
        )
    };
    let reference = run_fleet(&jobs, Some(1), run_job);
    for workers in [2, 8] {
        let got = run_fleet(&jobs, Some(workers), run_job);
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
            assert_eq!(a.rounds.len(), b.rounds.len(), "job {i} workers {workers}");
            assert_eq!(a.power_cycles, b.power_cycles, "job {i} workers {workers}");
            assert_eq!(a.app_energy, b.app_energy, "job {i} workers {workers}");
            for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
                assert_eq!(ra.emitted_at, rb.emitted_at, "job {i} workers {workers}");
                assert_eq!(ra.steps_executed, rb.steps_executed);
                assert_eq!(ra.output, rb.output);
            }
        }
    }
}

#[test]
fn shared_har_context_fleet_is_deterministic_across_pool_sizes() {
    // Figure sweeps train the HAR context once and share it read-only
    // across every fleet job; determinism must not depend on the pool
    // size the shared context is consumed under.
    let ctx = test_context();
    let spec = HarRunSpec { horizon: 900.0, ..Default::default() };
    let jobs: Vec<(Policy, u64)> = [Policy::Greedy, Policy::Chinchilla]
        .iter()
        .flat_map(|&p| [1u64, 2u64].map(|v| (p, v)))
        .collect();
    let run_job = |&(p, v): &(Policy, u64)| {
        run_har_policy(&ctx, &HarRunSpec { script_seed: v, ..spec.clone() }, p)
    };
    let reference = run_fleet(&jobs, Some(1), run_job);
    for workers in [2, 8] {
        let got = run_fleet(&jobs, Some(workers), run_job);
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
            assert_eq!(a.rounds.len(), b.rounds.len(), "job {i} workers {workers}");
            assert_eq!(a.power_cycles, b.power_cycles, "job {i} workers {workers}");
            assert_eq!(a.app_energy, b.app_energy, "job {i} workers {workers}");
            for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
                assert_eq!(ra.emitted_at, rb.emitted_at, "job {i} workers {workers}");
                assert_eq!(ra.steps_executed, rb.steps_executed);
            }
        }
    }
}

/// Bitwise campaign comparison for the cached-sweep gates below.
fn assert_audio_grids_identical(
    reference: &[Campaign<AudioOutput>],
    got: &[Campaign<AudioOutput>],
    label: &str,
) {
    assert_eq!(reference.len(), got.len(), "{label}: grid size");
    for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
        assert_eq!(a.rounds.len(), b.rounds.len(), "{label} cell {i}: rounds");
        assert_eq!(a.power_cycles, b.power_cycles, "{label} cell {i}");
        assert_eq!(a.power_failures, b.power_failures, "{label} cell {i}");
        assert_eq!(a.app_energy, b.app_energy, "{label} cell {i}");
        assert_eq!(a.state_energy, b.state_energy, "{label} cell {i}");
        for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
            assert_eq!(ra.acquired_at, rb.acquired_at, "{label} cell {i}");
            assert_eq!(ra.emitted_at, rb.emitted_at, "{label} cell {i}");
            assert_eq!(ra.steps_executed, rb.steps_executed, "{label} cell {i}");
            assert_eq!(ra.output, rb.output, "{label} cell {i}");
        }
    }
}

#[test]
fn cached_mixed_harvester_sweep_is_bitwise_identical_for_any_pool_size() {
    // The tentpole determinism gate: one scenario mixing all three
    // harvester families, run uncached single-threaded as the reference,
    // then with a shared supply cache under several worker-pool sizes.
    // Sharing one materialised supply across cells must change nothing
    // in any campaign, bit for bit — and the cache must build exactly
    // one supply per distinct (harvester, seed) pair, not per cell.
    let scenario = Scenario::new("cache_matrix", WorkloadSpec::Audio)
        .with_harvesters(vec![
            HarvesterSpec::Synth(SynthSpec::builtin_solar()),
            HarvesterSpec::Ambient(TraceKind::Rf),
            HarvesterSpec::Kinetic,
        ])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0)
        .with_sample_period(30.0);
    let distinct_supplies = 3 * 2; // harvesters × seeds (policies share)
    let cells = scenario.plan().len();
    assert_eq!(cells, 3 * 2 * 2, "grid shape changed under this test");

    let reference = scenario.run_cached(false, None, Some(1), &SupplyCache::disabled());
    for workers in [1usize, 2, 8] {
        let cache = SupplyCache::new();
        let got = scenario.run_cached(false, None, Some(workers), &cache);
        assert_audio_grids_identical(
            reference.audio_campaigns(),
            got.audio_campaigns(),
            &format!("workers={workers}"),
        );
        assert_eq!(
            cache.builds(),
            distinct_supplies as u64,
            "workers={workers}: builds must equal distinct supplies, not {cells} cells"
        );
        assert_eq!(cache.len(), distinct_supplies, "workers={workers}: cache entries");
    }
}

#[test]
fn supply_builds_track_distinct_supplies_across_a_device_grid() {
    // Devices vary capacitor sizing, not the energy environment, so a
    // P×D×S grid must still build one supply per (harvester, seed) —
    // and re-running the sweep on the same cache must build nothing new.
    let scenario = Scenario::new("cache_devices", WorkloadSpec::Audio)
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
        .with_devices(vec![
            DeviceSpec::default(),
            DeviceSpec { capacitance: Some(1000e-6), ..DeviceSpec::default() },
        ])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0)
        .with_sample_period(30.0);
    assert_eq!(scenario.plan().len(), 1 * 2 * 2 * 2);

    let cache = SupplyCache::new();
    let first = scenario.run_cached(false, None, None, &cache);
    assert_eq!(cache.builds(), 2, "one build per (harvester, seed), devices share");

    let second = scenario.run_cached(false, None, None, &cache);
    assert_eq!(cache.builds(), 2, "a warm cache must not rebuild supplies");
    assert_audio_grids_identical(
        first.audio_campaigns(),
        second.audio_campaigns(),
        "warm-cache rerun",
    );
}

#[test]
fn fleet_results_are_identical_across_worker_pool_sizes() {
    let jobs: Vec<(Policy, f64)> = all_policies()
        .into_iter()
        .flat_map(|p| [(p, 0.4e-3), (p, 1.2e-3)])
        .collect();
    let reference: Vec<Campaign<usize>> =
        run_fleet(&jobs, Some(1), |&(p, power)| run_policy(p, power));
    for workers in [2, 4, 16] {
        let got = run_fleet(&jobs, Some(workers), |&(p, power)| run_policy(p, power));
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
            assert_eq!(a.rounds.len(), b.rounds.len(), "job {i} workers {workers}");
            assert_eq!(a.power_cycles, b.power_cycles, "job {i} workers {workers}");
            assert_eq!(a.power_failures, b.power_failures, "job {i} workers {workers}");
            assert_eq!(a.app_energy, b.app_energy, "job {i} workers {workers}");
            assert_eq!(a.state_energy, b.state_energy, "job {i} workers {workers}");
            for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
                assert_eq!(ra.emitted_at, rb.emitted_at, "job {i} workers {workers}");
                assert_eq!(ra.steps_executed, rb.steps_executed);
                assert_eq!(ra.output, rb.output);
            }
        }
    }
}
