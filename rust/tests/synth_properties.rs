//! The statistical test layer gating the `energy::synth` generator.
//!
//! Three layers of evidence, mirroring the engine-equivalence suite:
//!
//! 1. **Seeded determinism** — the same `SynthSpec` realises the same
//!    `Piecewise` bit for bit, on any thread, and a synth sweep is
//!    bitwise identical for any fleet worker count (`AIC_WORKERS`
//!    equivalent).
//! 2. **Statistical invariants** — every generated environment is
//!    physically sane (finite non-negative powers, strictly increasing
//!    segment ends closing exactly at the pattern duration, prefix
//!    energies consistent with `energy_per_period`), and realised mean
//!    power stays within a sampling tolerance of the spec's analytic
//!    [`mean_power_band`](aic::energy::synth::SynthSpec::mean_power_band).
//! 3. **Engine equivalence** — GREEDY campaigns on one supply per
//!    source model (plus the multi-source composite) agree between the
//!    analytic engine and the fixed-step reference within the shared
//!    [`assert_campaigns_close`] tolerance contract.

use aic::coordinator::scenario::{HarvesterSpec, Scenario, WorkloadSpec};
use aic::energy::harvester::Harvester;
use aic::energy::synth::{
    Combine, KineticSurrogateSpec, SourceSpec, SynthSpec, ThermalSpec,
};
use aic::exec::approx::{run as run_approx, ApproxConfig};
use aic::exec::engine::{Engine, EngineConfig, EngineKind};
use aic::exec::program::SyntheticProgram;
use aic::exec::Policy;
use aic::util::testkit::assert_campaigns_close;

/// One single-source spec per model, plus the builtin composite — the
/// family set every test sweeps.
fn family_specs() -> Vec<SynthSpec> {
    let single = |name: &str, seed: u64, source: SourceSpec| SynthSpec {
        name: name.to_string(),
        seed,
        duration: 1800.0,
        combine: Combine::Sum,
        switch_efficiency: 1.0,
        sources: vec![source],
    };
    vec![
        SynthSpec::builtin_solar(),
        SynthSpec::builtin_rf(),
        single(
            "thermal-only",
            41,
            SourceSpec::Thermal(ThermalSpec {
                base: 1e-4,
                amplitude: 4e-4,
                period: 600.0,
                env_dt: 10.0,
                noise: 0.1,
            }),
        ),
        single(
            "kinetic-only",
            43,
            SourceSpec::Kinetic(KineticSurrogateSpec {
                mean_power: 1.2e-3,
                max_power: 8e-3,
                mean_active: 120.0,
                mean_rest: 90.0,
                tau: 10.0,
                rel_sigma: 0.5,
                env_dt: 2.0,
            }),
        ),
        SynthSpec::builtin_multi(),
    ]
}

#[test]
fn builds_are_bit_identical_across_threads() {
    for spec in family_specs() {
        let reference = spec.build(7);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let spec = spec.clone();
                std::thread::spawn(move || spec.build(7))
            })
            .collect();
        for h in handles {
            let pw = h.join().expect("builder thread panicked");
            assert_eq!(pw.ends, reference.ends, "{}", spec.name);
            assert_eq!(pw.powers, reference.powers, "{}", spec.name);
            assert_eq!(pw.period, reference.period, "{}", spec.name);
        }
    }
}

#[test]
fn synth_sweep_is_bitwise_identical_for_any_worker_count() {
    // The full scenario path (plan → fleet → grid) on a generated
    // supply: a 1-worker pool and a wide pool must produce the same
    // campaigns bit for bit — generation happens inside fleet workers,
    // so this is the "same Piecewise across AIC_WORKERS values" gate.
    let sc = Scenario::new("synth-workers", WorkloadSpec::Audio)
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_multi())])
        .with_seeds(vec![1, 2, 3])
        .with_horizon(600.0);
    let solo = sc.run_with(false, None, Some(1));
    let wide = sc.run_with(false, None, Some(4));
    let (a, b) = (solo.audio_campaigns(), wide.audio_campaigns());
    assert_eq!(a.len(), b.len());
    for (i, (ca, cb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ca.power_cycles, cb.power_cycles, "cell {i}");
        assert_eq!(ca.power_failures, cb.power_failures, "cell {i}");
        assert_eq!(ca.app_energy.to_bits(), cb.app_energy.to_bits(), "cell {i}");
        assert_eq!(ca.state_energy.to_bits(), cb.state_energy.to_bits(), "cell {i}");
        assert_eq!(ca.rounds.len(), cb.rounds.len(), "cell {i}");
        for (ra, rb) in ca.rounds.iter().zip(&cb.rounds) {
            assert_eq!(ra.acquired_at.to_bits(), rb.acquired_at.to_bits(), "cell {i}");
            assert_eq!(ra.emitted_at.is_some(), rb.emitted_at.is_some(), "cell {i}");
            assert_eq!(ra.steps_executed, rb.steps_executed, "cell {i}");
        }
    }
}

#[test]
fn shared_supplies_do_not_perturb_synth_campaigns() {
    // Supply sharing is an allocation optimisation, not a modelling
    // change: a generated-environment sweep must realise bit-identical
    // campaigns whether every cell builds its own `Piecewise` or all
    // cells of a (harvester, seed) share one cached supply. Environment
    // generation is the expensive, stateful part of these sweeps, so
    // this is where a cache that leaked cursor state would show first.
    use aic::coordinator::experiment::SupplyCache;
    let sc = Scenario::new("synth-cache", WorkloadSpec::Audio)
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_harvesters(vec![
            HarvesterSpec::Synth(SynthSpec::builtin_multi()),
            HarvesterSpec::Synth(SynthSpec::builtin_solar()),
        ])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0);
    let cache = SupplyCache::new();
    let shared = sc.run_cached(false, None, None, &cache);
    let private = sc.run_cached(false, None, None, &SupplyCache::disabled());
    assert_eq!(cache.builds(), 4, "2 synth families x 2 seeds");
    let (a, b) = (shared.audio_campaigns(), private.audio_campaigns());
    assert_eq!(a.len(), b.len());
    for (i, (ca, cb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ca.power_cycles, cb.power_cycles, "cell {i}");
        assert_eq!(ca.power_failures, cb.power_failures, "cell {i}");
        assert_eq!(ca.app_energy.to_bits(), cb.app_energy.to_bits(), "cell {i}");
        assert_eq!(ca.state_energy.to_bits(), cb.state_energy.to_bits(), "cell {i}");
        assert_eq!(ca.rounds.len(), cb.rounds.len(), "cell {i}");
        for (ra, rb) in ca.rounds.iter().zip(&cb.rounds) {
            assert_eq!(ra.acquired_at.to_bits(), rb.acquired_at.to_bits(), "cell {i}");
            assert_eq!(ra.emitted_at, rb.emitted_at, "cell {i}");
            assert_eq!(ra.steps_executed, rb.steps_executed, "cell {i}");
            assert_eq!(ra.output, rb.output, "cell {i}");
        }
    }
}

#[test]
fn generated_environments_are_physically_sane() {
    for spec in family_specs() {
        for seed in 1..=8 {
            let pw = spec.build(seed);
            let name = format!("{} seed {seed}", spec.name);
            assert_eq!(pw.period, spec.duration, "{name}");
            assert_eq!(*pw.ends.last().unwrap(), spec.duration, "{name}");
            assert!(
                pw.powers.iter().all(|&p| p.is_finite() && p >= 0.0),
                "{name}: non-finite or negative power"
            );
            let mut prev = 0.0;
            for &e in &pw.ends {
                assert!(e > prev, "{name}: segment ends not strictly increasing");
                prev = e;
            }
            // Prefix energies over one period sum to energy_per_period,
            // and the segment iterator tiles time against point samples.
            let h = Harvester::Synth(pw.clone());
            let mut prefix = 0.0;
            let mut cursor = 0.0;
            for seg in h.segments(0.0) {
                if seg.start >= spec.duration {
                    break;
                }
                assert_eq!(seg.start, cursor, "{name}: segment seam");
                let end = seg.end.min(spec.duration);
                prefix += seg.power * (end - seg.start);
                let mid = 0.5 * (seg.start + end);
                assert_eq!(seg.power, pw.power_at(mid), "{name}: point sample");
                cursor = seg.end;
            }
            let per_period = pw.energy_per_period();
            assert!(
                (prefix - per_period).abs() <= 1e-12 * per_period.max(1e-9),
                "{name}: prefix energy {prefix} vs period energy {per_period}"
            );
            assert!(
                (pw.mean_power() - per_period / spec.duration).abs() < 1e-18,
                "{name}"
            );
        }
    }
}

#[test]
fn realised_mean_power_stays_in_the_spec_band() {
    // Sampling tolerance: 1800 s patterns averaged over 8 family
    // members put even the slowest process (kinetic bouts, ~10 per
    // pattern) near its expectation; the [0.5, 1.6] factors leave room
    // for the clamping bias the analytic band ignores.
    for spec in family_specs() {
        let (lo, hi) = spec.mean_power_band();
        assert!(lo > 0.0 && lo <= hi, "{}: degenerate band", spec.name);
        let seeds = 1..=8u64;
        let n = 8.0;
        let mean: f64 = seeds.map(|s| spec.build(s).mean_power()).sum::<f64>() / n;
        assert!(
            mean >= 0.5 * lo && mean <= 1.6 * hi,
            "{}: realised mean {mean} outside band [{lo}, {hi}]",
            spec.name
        );
    }
}

#[test]
fn environment_seeds_are_decorrelated() {
    // Different cell seeds give different family members — and not just
    // one differing segment: the realised means themselves spread.
    let spec = SynthSpec::builtin_rf();
    let means: Vec<f64> = (1..=6).map(|s| spec.build(s).mean_power()).collect();
    for i in 0..means.len() {
        for j in (i + 1)..means.len() {
            assert_ne!(
                means[i].to_bits(),
                means[j].to_bits(),
                "seeds {} and {} realised identical environments",
                i + 1,
                j + 1
            );
        }
    }
}

#[test]
fn analytic_engine_matches_reference_on_every_source_model() {
    // The synth twin of the engine-equivalence campaign goldens: GREEDY
    // anytime campaigns on each generated family, analytic vs the
    // fixed-step reference, through the shared tolerance contract.
    for spec in family_specs() {
        let h = Harvester::Synth(spec.build(5));
        let mut ac = EngineConfig::paper_default(1800.0);
        ac.kind = EngineKind::Analytic;
        ac.initial_voltage = 3.0;
        let mut rc = EngineConfig::reference(1800.0);
        rc.initial_voltage = 3.0;
        let mut a = Engine::new(ac, h.clone());
        let mut r = Engine::new(rc, h);
        let mut pa = SyntheticProgram::new(1000, 140, 300_000);
        let mut pr = SyntheticProgram::new(1000, 140, 300_000);
        let ca = run_approx(&mut pa, &mut a, &ApproxConfig::greedy(60.0));
        let cr = run_approx(&mut pr, &mut r, &ApproxConfig::greedy(60.0));
        assert!(
            cr.emitted().count() > 0,
            "{}: reference campaign emitted nothing",
            spec.name
        );
        assert_campaigns_close(&spec.name, &ca, &cr);
    }
}

#[test]
fn ten_environment_seed_grid_completes_on_the_analytic_engine() {
    // The acceptance grid in miniature: ten generated family members,
    // explicitly pinned to the analytic engine (no AIC_ENGINE fallback),
    // run end to end through plan -> fleet -> projection. The generator
    // emits `Piecewise` natively, so nothing on this path touches a
    // sampling grid.
    use aic::coordinator::scenario::DeviceSpec;
    let sc = Scenario::new("synth-ten", WorkloadSpec::Audio)
        .with_policies(vec![Policy::Greedy])
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
        .with_devices(vec![DeviceSpec {
            engine: Some(EngineKind::Analytic),
            ..DeviceSpec::default()
        }])
        .with_seeds((1..=10).collect())
        .with_horizon(300.0);
    let run = sc.run(false);
    let campaigns = run.audio_campaigns();
    assert_eq!(campaigns.len(), 10);
    for (i, c) in campaigns.iter().enumerate() {
        assert!(!c.rounds.is_empty(), "environment seed {} produced no rounds", i + 1);
    }
    let tables = run.tables();
    assert_eq!(tables[0].rows.len(), 10, "one row per environment seed");
}

#[test]
fn committed_synth_examples_stay_in_lockstep_with_the_builtins() {
    // The example scenario files embed the same specs the `synth_*`
    // builtin registry and the benches construct in code; if either
    // side drifts, a sweep of the committed file would silently stop
    // reproducing `aic synth_*`.
    use aic::coordinator::scenario::builtin;
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios");
    for (file, name) in [
        ("synth_solar.json", "synth_solar"),
        ("synth_rf.json", "synth_rf"),
        ("synth_multi.json", "synth_multi"),
    ] {
        let text = std::fs::read_to_string(format!("{dir}/{file}"))
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        let sc = Scenario::parse(&text).unwrap_or_else(|e| panic!("{file}: {e}"));
        let want = builtin(name, 42).expect("registered builtin");
        // Everything that defines the grid and its realisation must
        // match the builtin — the file may word its title differently,
        // but a drift in supplies, policies, seeds, timing, fast-mode
        // scaling or projection would make `aic sweep <file>` silently
        // stop reproducing `aic <name>`.
        assert_eq!(sc.harvesters, want.harvesters, "{file}: supply drifted");
        assert_eq!(sc.policies, want.policies, "{file}: policies drifted");
        assert_eq!(sc.seeds, want.seeds, "{file}: seeds drifted");
        assert_eq!(sc.horizon, want.horizon, "{file}: horizon drifted");
        assert_eq!(sc.sample_period, want.sample_period, "{file}: period drifted");
        assert_eq!(sc.devices, want.devices, "{file}: devices drifted");
        assert_eq!(sc.fast, want.fast, "{file}: fast mode drifted");
        assert_eq!(sc.projection, want.projection, "{file}: projection drifted");
        assert_eq!(sc.training, want.training, "{file}: training drifted");
        assert!(sc.seeds.len() >= 10, "{file}: fewer than 10 environment seeds");
        let HarvesterSpec::Synth(spec) = &sc.harvesters[0] else {
            panic!("{file}: expected a synth harvester");
        };
        spec.validate().unwrap_or_else(|e| panic!("{file}: {e}"));
    }
}
