//! The scenario API's contract tests: lossless JSON round-trips with
//! identical job plans, row-level parity of the scenario-driven figures
//! against the retired per-figure wiring (figs. 5 and 13), fleet
//! determinism for any worker-pool size, and a parse gate over the
//! committed example scenarios in `examples/scenarios/`.

use aic::coordinator::experiment::{run_har_policy, run_img_policy, HarRunSpec, ImgRunSpec};
use aic::coordinator::metrics;
use aic::coordinator::scenario::{
    builtin, har_policies, HarvesterSpec, Scenario, Training, WorkloadSpec, BUILTIN_NAMES,
};
use aic::coordinator::sink::pct;
use aic::energy::traces::TraceKind;
use aic::exec::{Campaign, Policy};
use aic::har::app::HarOutput;
use aic::imgproc::images::EVAL_SIZE;
use aic::util::stats::mean;

#[test]
fn builtin_scenarios_round_trip_through_json() {
    for name in BUILTIN_NAMES {
        let sc = builtin(name, 42).unwrap();
        let text = sc.to_json_string();
        let parsed = Scenario::parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(parsed, sc, "{name}: scenario changed across the round trip");
        assert_eq!(parsed.plan(), sc.plan(), "{name}: job plan changed");
        // The fast resolution survives the round trip too.
        assert_eq!(parsed.resolve(true).plan(), sc.resolve(true).plan(), "{name}: fast plan");
    }
}

#[test]
fn example_scenarios_parse_and_round_trip() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).expect("examples/scenarios missing") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        seen += 1;
        let text = std::fs::read_to_string(&path).unwrap();
        let sc = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(!sc.plan().is_empty(), "{}: expands to an empty grid", path.display());
        let rt = Scenario::parse(&sc.to_json_string()).unwrap();
        assert_eq!(rt.plan(), sc.plan(), "{}: plan changed across round trip", path.display());
    }
    assert!(seen >= 1, "no committed example scenarios found in {dir}");
}

/// Fig. 5 parity: the scenario-driven sweep reproduces — bit for bit —
/// what the retired `har_policy_comparison`/`summarise_policies` wiring
/// computed, down to the formatted table rows the CLI prints.
#[test]
fn scenario_fig5_matches_legacy_rows() {
    // The CLI's `aic fig5 --fast` configuration (tiny corpus, two
    // volunteers, 30-minute horizon) keeps the oracle affordable.
    let sc = builtin("fig5", 42).unwrap().resolve(true);
    assert_eq!(sc.training, Training::tiny());
    let ctx = sc.har_context();
    let run = sc.run_with(false, Some(&ctx), None);
    let rows = run.policy_rows();

    // --- the legacy oracle: one campaign per (policy, volunteer), then
    // the exact summarise_policies arithmetic ---------------------------
    let policies = har_policies();
    let volunteers = sc.seeds.clone();
    let spec =
        HarRunSpec { horizon: sc.horizon, sample_period: sc.sample_period, script_seed: 0 };
    let campaigns: Vec<Vec<Campaign<HarOutput>>> = policies
        .iter()
        .map(|&p| {
            volunteers
                .iter()
                .map(|&v| {
                    run_har_policy(&ctx, &HarRunSpec { script_seed: v, ..spec.clone() }, p)
                })
                .collect()
        })
        .collect();
    let idx = |p: Policy| policies.iter().position(|&q| q == p).unwrap();
    let (cont, chin, greedy) =
        (idx(Policy::Continuous), idx(Policy::Chinchilla), idx(Policy::Greedy));
    let per_volunteer = |f: &dyn Fn(usize) -> f64| -> f64 {
        let v: Vec<f64> = (0..volunteers.len()).map(f).collect();
        mean(&v)
    };

    assert_eq!(rows.len(), policies.len());
    for (i, &policy) in policies.iter().enumerate() {
        let r = &rows[i];
        assert_eq!(r.policy, policy);
        let accuracy = per_volunteer(&|v| metrics::har_accuracy(&campaigns[i][v]));
        let coh_cont = per_volunteer(&|v| {
            metrics::har_coherence(&campaigns[i][v], &campaigns[cont][v], spec.sample_period)
        });
        let coh_chin = per_volunteer(&|v| {
            metrics::har_coherence(&campaigns[i][v], &campaigns[chin][v], spec.sample_period)
        });
        let thr_cont = per_volunteer(&|v| {
            metrics::throughput_ratio(&campaigns[i][v], &campaigns[cont][v])
        });
        let thr_greedy = per_volunteer(&|v| {
            metrics::throughput_ratio(&campaigns[i][v], &campaigns[greedy][v])
        });
        let thr_chin = per_volunteer(&|v| {
            metrics::throughput_ratio(&campaigns[i][v], &campaigns[chin][v])
        });
        let mean_features = per_volunteer(&|v| {
            let steps: Vec<f64> =
                campaigns[i][v].emitted().map(|r| r.steps_executed as f64).collect();
            mean(&steps)
        });
        let state_frac = per_volunteer(&|v| {
            let c = &campaigns[i][v];
            let total = c.app_energy + c.state_energy;
            if total == 0.0 {
                0.0
            } else {
                c.state_energy / total
            }
        });
        // Bit-for-bit: same campaigns, same means, same order.
        assert_eq!(r.accuracy, accuracy, "{policy:?} accuracy");
        assert_eq!(r.coherence_vs_continuous, coh_cont, "{policy:?} coherence/cont");
        assert_eq!(r.coherence_vs_chinchilla, coh_chin, "{policy:?} coherence/chin");
        assert_eq!(r.throughput_vs_continuous, thr_cont, "{policy:?} thrpt/cont");
        assert_eq!(r.throughput_vs_greedy, thr_greedy, "{policy:?} thrpt/greedy");
        assert_eq!(r.throughput_vs_chinchilla, thr_chin, "{policy:?} thrpt/chin");
        assert_eq!(r.mean_features, mean_features, "{policy:?} mean features");
        assert_eq!(r.state_energy_fraction, state_frac, "{policy:?} state fraction");
    }

    // The rendered table matches the legacy CLI formatting row for row.
    let tables = run.tables();
    assert_eq!(tables.len(), 1);
    for (i, row) in tables[0].rows.iter().enumerate() {
        let r = &rows[i];
        let expected = vec![
            r.policy.name(),
            pct(r.accuracy),
            pct(r.throughput_vs_continuous),
            format!("{:.2}", r.mean_features),
            pct(r.state_energy_fraction),
        ];
        assert_eq!(row, &expected, "fig5 row {i}");
    }
}

/// Fig. 13 parity: the scenario-driven sweep reproduces the retired
/// `fig13_by_picture` + `img_trace_comparison` tables row for row.
#[test]
fn scenario_fig13_matches_legacy_rows() {
    // Short horizon keeps the 5-trace x 3-policy grid affordable.
    let sc = builtin("fig13", 9).unwrap().with_horizon(600.0);
    let run = sc.run(false);
    let tables = run.tables();
    assert_eq!(tables.len(), 2, "fig13 emits the pooled + per-trace tables");

    // --- the legacy oracle: one GREEDY campaign per trace -------------
    let spec = ImgRunSpec { horizon: 600.0, sample_period: 30.0, trace_seed: 9 };
    let greedy: Vec<_> = TraceKind::ALL
        .iter()
        .map(|&t| run_img_policy(&spec, t, Policy::Greedy))
        .collect();
    let refs: Vec<&Campaign<_>> = greedy.iter().collect();
    let by_picture = metrics::corner_equivalence_by_picture(&refs, EVAL_SIZE);
    let expected_pooled: Vec<Vec<String>> = by_picture
        .iter()
        .map(|(picture, eq)| vec![picture.name().to_string(), pct(*eq)])
        .collect();
    assert_eq!(tables[0].rows, expected_pooled, "fig13 pooled-by-picture rows");

    let expected_per_trace: Vec<Vec<String>> = TraceKind::ALL
        .iter()
        .zip(&greedy)
        .map(|(t, c)| {
            vec![t.name().to_string(), pct(metrics::corner_equivalence_fraction(c, EVAL_SIZE))]
        })
        .collect();
    assert_eq!(tables[1].rows, expected_per_trace, "fig13 per-trace rows");
}

/// The acceptance gate: a sweep's rows are identical under any worker
/// pool size (`AIC_WORKERS` equivalent), on a grid mixing harvesters.
#[test]
fn sweep_rows_identical_for_any_worker_count() {
    let sc = Scenario::new("workers", WorkloadSpec::Har)
        .with_training(Training::tiny())
        .with_policies(vec![Policy::Greedy, Policy::Continuous])
        .with_harvesters(vec![
            HarvesterSpec::Kinetic,
            HarvesterSpec::Ambient(TraceKind::Som),
        ])
        .with_seeds(vec![1, 2])
        .with_horizon(900.0);
    let ctx = sc.har_context();
    let one = sc.run_with(false, Some(&ctx), Some(1)).tables();
    let many = sc.run_with(false, Some(&ctx), Some(7)).tables();
    assert_eq!(one, many, "sweep output depends on the pool size");
    // 2 harvesters x 2 policies x 2 seeds = 8 cells, one row each.
    assert_eq!(one[0].rows.len(), 8);
}

/// The committed HAR-on-ambient-traces scenario (the grid no hard-coded
/// figure ever covered) runs end-to-end in fast mode.
#[test]
fn har_ambient_example_runs_fast() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/scenarios/har_ambient.json"
    );
    let sc = Scenario::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert!(
        sc.harvesters.iter().all(|h| matches!(h, HarvesterSpec::Ambient(_))),
        "the example is about ambient supplies"
    );
    let run = sc.run(true);
    let tables = run.tables();
    assert_eq!(tables[0].rows.len(), run.scenario.plan().len());
}
