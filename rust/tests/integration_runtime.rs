//! PJRT runtime integration: load the AOT artifacts and cross-check
//! their numerics against the pure-Rust twins.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise —
//! `make test` guarantees the ordering).

use aic::runtime::{ArtifactRuntime, Tensor};
use aic::util::rng::Rng;

fn runtime() -> Option<ArtifactRuntime> {
    match ArtifactRuntime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e}); run `make artifacts`");
            None
        }
    }
}

fn rand_vec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.gaussian() * scale) as f32).collect()
}

#[test]
fn all_manifest_artifacts_load_and_execute() {
    let Some(rt) = runtime() else { return };
    assert_eq!(
        rt.names(),
        vec![
            "feature_stats",
            "har_e2e",
            "harris",
            "spectral_power",
            "svm_incremental",
            "svm_prefix"
        ]
    );
    for name in rt.names() {
        let shapes = rt.input_shapes(&name);
        assert!(!shapes.is_empty(), "{name} missing input shapes");
        let inputs: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s.clone())).collect();
        let out = rt.execute(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!out.data.is_empty());
    }
}

#[test]
fn svm_prefix_matches_rust_scores() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let (b, n, c) = (256usize, 140usize, 6usize);
    let x = rand_vec(&mut rng, b * n, 1.0);
    let w = rand_vec(&mut rng, c * n, 0.2);
    let bias = rand_vec(&mut rng, c, 0.5);
    let p = 77usize;
    let mask: Vec<f32> = (0..n).map(|j| if j < p { 1.0 } else { 0.0 }).collect();
    let out = rt
        .execute(
            "svm_prefix",
            &[
                Tensor::new(vec![b, n], x.clone()),
                Tensor::new(vec![c, n], w.clone()),
                Tensor::new(vec![c], bias.clone()),
                Tensor::new(vec![n], mask),
            ],
        )
        .unwrap();
    assert_eq!(out.shape, vec![b, c]);
    // Rust twin: masked dot products.
    for i in 0..b {
        for k in 0..c {
            let mut s = bias[k] as f64;
            for j in 0..p {
                s += x[i * n + j] as f64 * w[k * n + j] as f64;
            }
            let got = out.data[i * c + k] as f64;
            assert!(
                (got - s).abs() < 1e-2 * (1.0 + s.abs()),
                "b={i} c={k}: got {got} want {s}"
            );
        }
    }
}

#[test]
fn spectral_power_matches_rust_fft() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let (b, t) = (256usize, 128usize);
    let x = rand_vec(&mut rng, b * t, 1.0);
    let out = rt.execute("spectral_power", &[Tensor::new(vec![b, t], x.clone())]).unwrap();
    assert_eq!(out.shape, vec![b, t / 2 + 1]);
    // Check a few rows against the Rust radix-2 FFT.
    for &row in &[0usize, 17, 255] {
        let signal: Vec<f64> = (0..t).map(|i| x[row * t + i] as f64).collect();
        let ps = aic::util::fft::power_spectrum(&signal);
        for k in 0..=t / 2 {
            let got = out.data[row * (t / 2 + 1) + k] as f64;
            assert!(
                (got - ps[k]).abs() < 1e-2 * (1.0 + ps[k]),
                "row={row} bin={k}: got {got} want {}",
                ps[k]
            );
        }
    }
}

#[test]
fn feature_stats_matches_rust_stats() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let (b, t) = (256usize, 128usize);
    let x = rand_vec(&mut rng, b * t, 2.0);
    let out = rt.execute("feature_stats", &[Tensor::new(vec![b, t], x.clone())]).unwrap();
    assert_eq!(out.shape, vec![b, 5]);
    for &row in &[0usize, 100, 255] {
        let signal: Vec<f64> = (0..t).map(|i| x[row * t + i] as f64).collect();
        let mean = aic::util::stats::mean(&signal);
        let std = aic::util::stats::std_dev(&signal);
        let energy = signal.iter().map(|v| v * v).sum::<f64>() / t as f64;
        let mn = signal.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = signal.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let want = [mean, std, energy, mn, mx];
        for (k, w) in want.iter().enumerate() {
            let got = out.data[row * 5 + k] as f64;
            assert!(
                (got - w).abs() < 1e-3 * (1.0 + w.abs()),
                "row={row} stat={k}: got {got} want {w}"
            );
        }
    }
}

#[test]
fn harris_artifact_matches_rust_detector_responses() {
    let Some(rt) = runtime() else { return };
    use aic::imgproc::harris::{gradients, response_row, HarrisConfig, ResponseMap};
    use aic::imgproc::images::{render, Picture};
    let size = 160usize;
    let img = render(Picture::Checker, size, size, 7);
    let data: Vec<f32> = img.data.iter().map(|&v| v as f32).collect();
    let mask = vec![1.0f32; size];
    let out = rt
        .execute(
            "harris",
            &[Tensor::new(vec![size, size], data), Tensor::new(vec![size], mask)],
        )
        .unwrap();
    assert_eq!(out.shape, vec![size, size]);
    // Rust twin.
    let (ix, iy) = gradients(&img);
    let cfg = HarrisConfig::default();
    let mut map = ResponseMap::new(size, size);
    for y in 0..size {
        response_row(&ix, &iy, &mut map, y, &cfg);
    }
    let mut max_abs: f64 = 0.0;
    for v in &map.r {
        max_abs = max_abs.max(v.abs());
    }
    for y in (8..size - 8).step_by(16) {
        for xcoord in (8..size - 8).step_by(16) {
            let got = out.data[y * size + xcoord] as f64;
            let want = map.r[y * size + xcoord];
            assert!(
                (got - want).abs() < 1e-3 * max_abs,
                "({xcoord},{y}): got {got} want {want}"
            );
        }
    }
}

#[test]
fn svm_incremental_chain_equals_prefix_artifact() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let (b, c, chunk) = (256usize, 6usize, 16usize);
    let n = 64usize; // 4 chunks
    let x = rand_vec(&mut rng, b * n, 1.0);
    let w = rand_vec(&mut rng, c * n, 0.2);
    let bias = rand_vec(&mut rng, c, 0.5);
    // Chain incremental updates.
    let mut s: Vec<f32> = (0..b).flat_map(|_| bias.clone()).collect();
    for lo in (0..n).step_by(chunk) {
        let xc: Vec<f32> = (0..b)
            .flat_map(|i| (lo..lo + chunk).map(move |j| (i, j)))
            .map(|(i, j)| x[i * n + j])
            .collect();
        let wc: Vec<f32> = (0..c)
            .flat_map(|k| (lo..lo + chunk).map(move |j| (k, j)))
            .map(|(k, j)| w[k * n + j])
            .collect();
        let out = rt
            .execute(
                "svm_incremental",
                &[
                    Tensor::new(vec![b, c], s.clone()),
                    Tensor::new(vec![b, chunk], xc),
                    Tensor::new(vec![c, chunk], wc),
                ],
            )
            .unwrap();
        s = out.data;
    }
    // Compare against direct dot products.
    for i in (0..b).step_by(37) {
        for k in 0..c {
            let mut want = bias[k] as f64;
            for j in 0..n {
                want += x[i * n + j] as f64 * w[k * n + j] as f64;
            }
            let got = s[i * c + k] as f64;
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want.abs()),
                "b={i} c={k}: got {got} want {want}"
            );
        }
    }
}
