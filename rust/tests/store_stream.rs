//! Streaming-sweep equivalence and store-backed resume.
//!
//! The streaming pipeline's contract (see `coordinator::stream`) is
//! *bitwise* equality with the batch path: for every projection, any
//! worker-pool size, any chunk size, and any kill/resume history, the
//! tables a streamed sweep emits must equal `SweepRun::tables()` down to
//! the formatted strings. These tests compare the two paths through
//! [`MemorySink`] (which reconstructs `TableData` exactly) and then
//! re-compare every rendered form — CSV, markdown, JSON — so a
//! float-formatting drift cannot hide behind `PartialEq`.

use aic::coordinator::experiment::{HarContext, SupplyCache};
use aic::coordinator::scenario::{
    har_policies, HarvesterSpec, Projection, Scenario, Training, WorkloadSpec,
};
use aic::coordinator::sink::{emit_all, MemorySink, TableData};
use aic::coordinator::store::Store;
use aic::coordinator::stream::{run_streaming, StreamOptions, StreamReport};
use aic::energy::traces::TraceKind;
use aic::exec::Policy;
use aic::util::json;
use std::path::PathBuf;

fn temp_store(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("aic_stream_{tag}_{}.aic", std::process::id()))
}

/// The batch reference: run the sweep eagerly and capture its tables.
fn batch_tables(sc: &Scenario, ctx: Option<&HarContext>, cache: &SupplyCache) -> Vec<TableData> {
    let run = sc.run_cached(false, ctx, Some(2), cache);
    let mut m = MemorySink::new();
    emit_all(&run.tables(), &mut m).unwrap();
    m.tables
}

fn stream_tables(
    sc: &Scenario,
    workers: usize,
    chunk: usize,
    ctx: Option<&HarContext>,
    cache: &SupplyCache,
    store: Option<&mut Store>,
) -> (Vec<TableData>, StreamReport) {
    let opts = StreamOptions { workers: Some(workers), chunk, ..StreamOptions::default() };
    let mut m = MemorySink::new();
    let report = run_streaming(sc, &opts, ctx, cache, store, &mut m).unwrap();
    (m.tables, report)
}

/// Every rendered byte of a table set, concatenated.
fn render(tables: &[TableData]) -> String {
    let mut s = String::new();
    for t in tables {
        s.push_str(&t.stem);
        s.push_str(&t.to_csv());
        s.push_str(&t.to_markdown());
        s.push_str(&json::to_string(&t.to_json()));
    }
    s
}

fn assert_stream_matches(
    sc: &Scenario,
    want: &[TableData],
    combos: &[(usize, usize)],
    ctx: Option<&HarContext>,
    cache: &SupplyCache,
    label: &str,
) {
    let cells = sc.plan().len();
    for &(workers, chunk) in combos {
        let (got, report) = stream_tables(sc, workers, chunk, ctx, cache, None);
        assert_eq!(
            report,
            StreamReport { cells, reused: 0, ran: cells, partial: false },
            "{label} workers={workers} chunk={chunk}: report"
        );
        assert_eq!(got, want, "{label} workers={workers} chunk={chunk}: tables");
        assert_eq!(
            render(&got),
            render(want),
            "{label} workers={workers} chunk={chunk}: rendered bytes"
        );
    }
}

/// Figs. 5/6/7/8/9 plus the raw cells view: the full HAR projection set,
/// on a grid mixing harvesters so the accumulators must flush more than
/// one (harvester, device) block.
#[test]
fn har_streaming_matches_batch_for_every_projection() {
    let base = Scenario::new("har_stream", WorkloadSpec::Har)
        .with_training(Training::tiny())
        .with_policies(har_policies())
        .with_harvesters(vec![
            HarvesterSpec::Kinetic,
            HarvesterSpec::Ambient(TraceKind::Som),
        ])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0);
    let ctx = base.har_context();
    let cache = SupplyCache::new();
    for proj in [
        Projection::Cells,
        Projection::PolicyAccuracy,
        Projection::PolicyCoherence,
        Projection::PolicyVsChinchilla,
        Projection::LatencyEmulation,
        Projection::LatencyRealWorld,
    ] {
        let sc = base.clone().with_projection(proj);
        let want = batch_tables(&sc, Some(&ctx), &cache);
        // chunk < block, chunk unaligned to the block, chunk > grid.
        assert_stream_matches(
            &sc,
            &want,
            &[(1, 1), (3, 5), (2, 64)],
            Some(&ctx),
            &cache,
            &format!("{proj:?}"),
        );
    }
}

#[test]
fn audio_streaming_matches_batch() {
    let base = Scenario::new("audio_stream", WorkloadSpec::Audio)
        .with_harvesters(vec![
            HarvesterSpec::Ambient(TraceKind::ALL[0]),
            HarvesterSpec::Ambient(TraceKind::ALL[1]),
        ])
        .with_policies(vec![Policy::Continuous, Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0)
        .with_sample_period(30.0);
    let cache = SupplyCache::new();
    for proj in [Projection::AudioSummary, Projection::Cells] {
        let sc = base.clone().with_projection(proj);
        let want = batch_tables(&sc, None, &cache);
        assert_stream_matches(&sc, &want, &[(1, 1), (2, 7)], None, &cache, &format!("{proj:?}"));
    }
}

#[test]
fn img_streaming_matches_batch() {
    let base = Scenario::new("img_stream", WorkloadSpec::Img)
        .with_harvesters(vec![
            HarvesterSpec::Ambient(TraceKind::ALL[0]),
            HarvesterSpec::Ambient(TraceKind::ALL[1]),
        ])
        .with_policies(vec![Policy::Continuous, Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1])
        .with_horizon(300.0)
        .with_sample_period(30.0);
    let cache = SupplyCache::new();
    for proj in [
        Projection::ImgEquivalence,
        Projection::ImgThroughput,
        Projection::ImgLatency,
        Projection::Cells,
    ] {
        let sc = base.clone().with_projection(proj);
        let want = batch_tables(&sc, None, &cache);
        assert_stream_matches(&sc, &want, &[(1, 1), (2, 4)], None, &cache, &format!("{proj:?}"));
    }
}

/// Fig. 4-style offline analyses are not campaigns; `run_streaming`
/// falls back to the batch path and must emit identical tables.
#[test]
fn non_campaign_workloads_fall_back_to_batch() {
    let sc = Scenario::new("curve_stream", WorkloadSpec::AccuracyCurve { ps: vec![0, 20] })
        .with_training(Training::tiny())
        .with_projection(Projection::AccuracyCurve);
    let cache = SupplyCache::new();
    let ctx = sc.har_context();
    let want = batch_tables(&sc, Some(&ctx), &cache);
    let (got, report) = stream_tables(&sc, 2, 8, Some(&ctx), &cache, None);
    assert!(!report.partial);
    assert_eq!(got, want);
    assert_eq!(render(&got), render(&want));
}

/// The acceptance gate: a campaign killed mid-sweep, resumed from its
/// store in a fresh "process" (a reopened `Store`), converges to the
/// byte-identical projections of an uninterrupted run — and a second
/// resume re-simulates nothing at all.
#[test]
fn killed_campaign_resumes_to_identical_bytes() {
    let sc = Scenario::new("resume_stream", WorkloadSpec::Audio)
        .with_harvesters(vec![
            HarvesterSpec::Ambient(TraceKind::ALL[0]),
            HarvesterSpec::Ambient(TraceKind::ALL[1]),
        ])
        .with_policies(vec![Policy::Continuous, Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1, 2])
        .with_horizon(300.0)
        .with_sample_period(30.0)
        .with_projection(Projection::AudioSummary);
    let cells = sc.plan().len();
    assert_eq!(cells, 12, "grid shape changed under this test");
    let cache = SupplyCache::new();

    // The uninterrupted references: batch, and store-less streaming.
    let want = batch_tables(&sc, None, &cache);
    let (uninterrupted, _) = stream_tables(&sc, 2, 3, None, &cache, None);
    assert_eq!(uninterrupted, want);

    let path = temp_store("resume");
    let _ = std::fs::remove_file(&path);

    // Leg 1: "SIGKILL" after 5 committed cells (the same abort point the
    // CI smoke drives through AIC_STREAM_KILL_AFTER).
    {
        let mut store = Store::open(&path).unwrap();
        let opts = StreamOptions {
            workers: Some(2),
            chunk: 3,
            stop_after: Some(5),
            ..StreamOptions::default()
        };
        let mut m = MemorySink::new();
        let report =
            run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert!(report.partial, "stop_after must abort the sweep");
    }

    // Leg 2: fresh open, different worker/chunk shape, run to the end.
    {
        let mut store = Store::open(&path).unwrap();
        assert_eq!(store.cell_count(), 5, "killed run must have committed 5 cells");
        let (got, report) = stream_tables(&sc, 3, 4, None, &cache, Some(&mut store));
        assert!(!report.partial);
        assert_eq!(report.reused, 5, "committed cells must not re-run");
        assert_eq!(report.ran, cells - 5);
        assert_eq!(got, want, "resumed projections drifted from the clean run");
        assert_eq!(render(&got), render(&want));
    }

    // Leg 3: everything is committed now — a re-run simulates nothing.
    {
        let mut store = Store::open(&path).unwrap();
        assert_eq!(store.cell_count(), cells);
        let (got, report) = stream_tables(&sc, 1, 64, None, &cache, Some(&mut store));
        assert_eq!(report.reused, cells);
        assert_eq!(report.ran, 0);
        assert_eq!(got, want);
    }

    // Leg 4: a crash mid-append leaves a torn tail; the resume still
    // converges to the same bytes and heals the file.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0x5A; 9]).unwrap();
    }
    {
        let mut store = Store::open(&path).unwrap();
        assert_eq!(store.salvaged_bytes(), 9);
        let (got, report) = stream_tables(&sc, 2, 5, None, &cache, Some(&mut store));
        assert_eq!(report.reused, cells);
        assert_eq!(got, want);
    }
    let _ = std::fs::remove_file(&path);
}

/// `aic store table` reconstructs — byte for byte — the cells table the
/// sweep itself emitted, from nothing but the store file.
#[test]
fn store_cells_table_matches_the_sweep_output() {
    let sc = Scenario::new("cells_view", WorkloadSpec::Audio)
        .with_harvesters(vec![HarvesterSpec::Ambient(TraceKind::ALL[0])])
        .with_policies(vec![Policy::Greedy, Policy::Chinchilla])
        .with_seeds(vec![1, 2])
        .with_horizon(300.0)
        .with_sample_period(30.0)
        .with_projection(Projection::Cells);
    let cache = SupplyCache::new();
    let path = temp_store("cells_view");
    let _ = std::fs::remove_file(&path);
    let mut store = Store::open(&path).unwrap();
    let (got, report) = stream_tables(&sc, 2, 2, None, &cache, Some(&mut store));
    assert_eq!(report.ran, sc.plan().len());
    let table = store.cells_table(None).unwrap();
    assert_eq!(got, vec![table], "store view must reproduce the sweep's cells table");
    let _ = std::fs::remove_file(&path);
}
