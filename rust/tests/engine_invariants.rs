//! Engine-level invariants across runtimes: energy conservation, the
//! approximate runtimes' single-cycle guarantee, Chinchilla's forward
//! progress, and ledger separation.

use aic::energy::harvester::Harvester;
use aic::energy::mcu::OpCost;
use aic::exec::approx::{run as run_approx, ApproxConfig};
use aic::exec::chinchilla::{run as run_chinchilla, ChinchillaConfig};
use aic::exec::engine::{Engine, EngineConfig, Ledger, OpOutcome};
use aic::exec::program::SyntheticProgram;
use aic::util::testkit::{property, Gen};

fn engine(power: f64, horizon: f64) -> Engine {
    Engine::new(EngineConfig::paper_default(horizon), Harvester::Constant(power))
}

#[test]
fn energy_is_conserved_per_operation() {
    property("op energy conservation", 128, |g: &mut Gen| {
        let power = g.f64_in(0.0..3e-3);
        let cycles = g.usize_in(1..=2_000_000) as u64;
        let mut e = engine(power, 1e9);
        let v0 = e.cap.energy();
        let cost = OpCost::cycles(cycles);
        let duration = e.mcu.duration(&cost);
        let spent = e.mcu.energy(&cost);
        let outcome = e.run_op(&cost, Ledger::App);
        if outcome == OpOutcome::Done {
            // Buffer change = harvested - spent, within booster bounds.
            let harvested_max = power * duration; // eta <= 1
            let delta = e.cap.energy() - v0;
            assert!(
                delta <= harvested_max - spent + 1e-12,
                "gained more than physically possible: delta={delta}"
            );
            assert!(
                delta >= -spent - 1e-12,
                "lost more than the op cost: delta={delta}"
            );
        }
    });
}

#[test]
fn dead_device_stays_dead_without_harvest() {
    let mut e = engine(0.0, 100.0);
    let _ = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App); // kill it
    assert!(!e.cap.alive());
    assert!(!e.charge_until_boot());
    assert!(e.out_of_time());
}

#[test]
fn approx_never_uses_state_ledger_and_stays_single_cycle() {
    property("approx single-cycle", 12, |g: &mut Gen| {
        let power = g.f64_in(5e-5..2e-3);
        let steps = g.usize_in(10..=200);
        let cycles = 50_000 + g.usize_in(0..=400_000) as u64;
        let mut prog = SyntheticProgram::new(1000, steps, cycles);
        let mut e = engine(power, 3600.0);
        let c = run_approx(&mut prog, &mut e, &ApproxConfig::greedy(60.0));
        assert_eq!(c.state_energy, 0.0, "approx must not manage persistent state");
        for r in c.emitted() {
            assert_eq!(r.latency_cycles, 0, "emitted result crossed a power failure");
        }
    });
}

#[test]
fn chinchilla_always_full_precision_and_makes_progress() {
    property("chinchilla progress", 8, |g: &mut Gen| {
        let power = g.f64_in(3e-4..2e-3);
        let steps = g.usize_in(20..=120);
        let mut prog = SyntheticProgram::new(3, steps, 300_000);
        let mut e = engine(power, 8.0 * 3600.0);
        let c = run_chinchilla(&mut prog, &mut e, &ChinchillaConfig::default());
        assert!(!c.rounds.is_empty(), "no forward progress");
        for r in c.emitted() {
            assert_eq!(r.steps_executed, steps, "chinchilla must be precise");
            assert_eq!(r.output, Some(steps));
        }
    });
}

#[test]
fn chinchilla_charges_the_state_ledger() {
    let mut prog = SyntheticProgram::new(2, 100, 400_000);
    let mut e = engine(0.5e-3, 4.0 * 3600.0);
    let c = run_chinchilla(&mut prog, &mut e, &ChinchillaConfig::default());
    assert!(c.state_energy > 0.0);
    assert!(c.power_failures > 0, "should have browned out at this power");
}

#[test]
fn horizon_is_respected_by_all_runtimes() {
    let horizon = 600.0;
    let mut p1 = SyntheticProgram::new(100_000, 50, 100_000);
    let mut e1 = engine(1e-3, horizon);
    let c1 = run_approx(&mut p1, &mut e1, &ApproxConfig::greedy(30.0));
    assert!(c1.duration <= horizon + 61.0, "approx overran: {}", c1.duration);

    let mut p2 = SyntheticProgram::new(100_000, 50, 100_000);
    let mut e2 = engine(1e-3, horizon);
    let c2 = run_chinchilla(&mut p2, &mut e2, &ChinchillaConfig::default());
    assert!(c2.duration <= horizon + 61.0, "chinchilla overran: {}", c2.duration);
}

#[test]
fn throughput_monotone_in_harvest_power() {
    let mut last = 0usize;
    for power in [1e-4, 3e-4, 1e-3] {
        let mut prog = SyntheticProgram::new(100_000, 100, 300_000);
        let mut e = engine(power, 3600.0);
        let c = run_approx(&mut prog, &mut e, &ApproxConfig::greedy(60.0));
        let emitted = c.emitted().count();
        assert!(
            emitted + 2 >= last,
            "more power should not reduce throughput: {emitted} < {last}"
        );
        last = emitted.max(last);
    }
}

#[test]
fn brownout_voids_partial_round_state() {
    // After a brown-out, the engine leaves the buffer below V_off and the
    // next boot requires the full recharge ramp.
    let mut e = engine(1e-3, 3600.0);
    let _ = e.run_op(&OpCost::cycles(3_000_000_000), Ledger::App);
    assert!(!e.cap.alive());
    let v = e.cap.voltage();
    assert!(v < e.cap.v_off && v > 0.0);
    assert!(e.charge_until_boot());
    // Back at V_on minus exactly the boot cost: the analytic engine
    // boots at the threshold crossing itself (the fixed-step reference
    // overshoots by up to one stride of charge).
    let after_boot =
        (2.0 * (e.cap.boot_energy_level() - e.mcu.boot_energy) / e.cap.capacitance).sqrt();
    assert!(e.cap.voltage() >= after_boot - 1e-9, "v={}", e.cap.voltage());
}
