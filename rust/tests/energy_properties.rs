//! Property tests for the analytic engine's energy core, on the in-tree
//! testkit/rng: the closed-form capacitor threshold crossing must
//! round-trip against brute-force stepped charging, the booster's warm
//! output must be monotone in input power, and the supply's piecewise
//! view (the engine's stepping table) must have non-decreasing prefix
//! energies and agree with point sampling — each across 1k randomized
//! (capacitance, threshold, trace-segment) draws.

use aic::energy::booster::Booster;
use aic::energy::capacitor::Capacitor;
use aic::energy::harvester::Harvester;
use aic::energy::traces::PowerTrace;
use aic::util::testkit::{property, Gen};

/// Random but physical capacitor: C in [100 µF, 10 mF], thresholds
/// ordered 0 < v_off < v_on <= v_max.
fn random_capacitor(g: &mut Gen) -> Capacitor {
    let c = g.f64_in(100e-6..10e-3).max(1e-6);
    let v_off = g.f64_in(0.5..2.0).max(0.1);
    let v_on = v_off + g.f64_in(0.2..1.5).max(0.01);
    let v_max = v_on + g.f64_in(0.0..1.0);
    Capacitor::new(c, v_max, v_on, v_off)
}

#[test]
fn time_to_energy_round_trips_against_stepped_charging() {
    property("time_to_energy vs stepping", 1000, |g: &mut Gen| {
        let mut cap = random_capacitor(g);
        // Start somewhere strictly inside [0, e_max].
        let e0 = g.f64_in(0.0..1.0).clamp(0.0, 1.0) * cap.max_energy();
        cap.set_energy(e0);
        let e0 = cap.energy();
        // Charge toward boot or drain toward brown-out.
        let charging = g.bool();
        let (target, net) = if charging {
            (cap.boot_energy_level(), g.f64_in(1e-6..5e-3).max(1e-9))
        } else {
            (cap.brownout_energy_level(), -g.f64_in(1e-6..5e-3).max(1e-9).abs())
        };
        match cap.time_to_energy(target, net) {
            Some(t) => {
                assert!(t >= 0.0, "negative crossing time {t}");
                // Brute-force: step e(t) = e0 + net·t in 1000 strides and
                // find the first stride that crosses the target.
                let dt = if t > 0.0 { t / 1000.0 } else { 1e-6 };
                let mut e = e0;
                let mut stepped = 0.0;
                let mut crossed = t == 0.0;
                for _ in 0..1100 {
                    if (net > 0.0 && e >= target) || (net < 0.0 && e <= target) {
                        crossed = true;
                        break;
                    }
                    e += net * dt;
                    stepped += dt;
                }
                assert!(crossed, "stepping never crossed the target");
                assert!(
                    (stepped - t).abs() <= dt + 1e-12,
                    "closed form {t} vs stepped {stepped} (dt {dt})"
                );
            }
            None => {
                // Unreachable means the gap and the net power disagree
                // in sign (or the power is zero) — stepping must move
                // away from (or never toward) the target.
                let gap = target - e0;
                assert!(
                    net == 0.0 || (gap > 0.0) != (net > 0.0) || gap == 0.0,
                    "closed form said unreachable for gap {gap} at net {net}"
                );
            }
        }
    });
}

#[test]
fn time_to_energy_inverts_exactly_on_the_paper_device() {
    property("time_to_energy inverse", 1000, |g: &mut Gen| {
        let mut cap = Capacitor::paper_default();
        cap.set_voltage(g.f64_in(0.0..3.6).clamp(0.0, 3.6));
        let e0 = cap.energy();
        let net = g.f64_in(1e-7..2e-3).max(1e-9);
        let t = g.f64_in(0.0..1e4).abs();
        // Where does constant-power charging land after t seconds?
        let target = (e0 + net * t).min(cap.max_energy());
        if target > e0 {
            let got = cap.time_to_energy(target, net).expect("reachable");
            assert!(
                (got - (target - e0) / net).abs() <= 1e-9 * (1.0 + got),
                "inverse broke: {got}"
            );
        }
    });
}

#[test]
fn booster_warm_output_is_monotone_in_input_power() {
    property("warm_output_power monotone", 1000, |g: &mut Gen| {
        // Random but physical booster: efficiency floor below peak,
        // positive knee, small quiescent draw.
        let eta_min = g.f64_in(0.05..0.5).clamp(0.01, 0.5);
        let booster = Booster {
            eta_min,
            eta_max: eta_min + g.f64_in(0.0..0.5).clamp(0.0, 0.5),
            knee_power: g.f64_in(1e-6..500e-6).max(1e-9),
            quiescent: g.f64_in(0.0..5e-6).max(0.0),
            cold_start_power: g.f64_in(0.0..50e-6).max(0.0),
        };
        let a = g.f64_in(0.0..10e-3).max(0.0);
        let b = g.f64_in(0.0..10e-3).max(0.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(
            booster.warm_output_power(lo) <= booster.warm_output_power(hi) + 1e-15,
            "warm output decreased from p={lo} to p={hi}"
        );
        // The warm output is what the engine's stepping table bakes in;
        // above the cold gate it must not depend on the buffer voltage.
        for v in [0.06, 1.0, 3.0] {
            assert_eq!(booster.output_power(hi, v), booster.warm_output_power(hi));
        }
    });
}

/// Random wrapping replay trace (zero-biased, like the RF profile).
fn random_trace(g: &mut Gen) -> PowerTrace {
    let n = g.usize_in(2..=200).max(2);
    let dt = g.f64_in(0.01..0.5).max(0.005);
    let samples: Vec<f64> = (0..n)
        .map(|_| if g.bool() { 0.0 } else { g.f64_in(0.0..5e-3).max(0.0) })
        .collect();
    PowerTrace { dt, samples }
}

#[test]
fn supply_prefix_energies_are_non_decreasing() {
    property("supply prefix energies", 1000, |g: &mut Gen| {
        let trace = random_trace(g);
        let h = Harvester::Replay(trace.clone());
        let pw = h.piecewise();
        let booster = Booster::paper_default();
        // Segment ends strictly increase and tile one period exactly.
        for i in 1..pw.len() {
            assert!(pw.ends[i] > pw.ends[i - 1], "segment ends not increasing");
        }
        assert!((pw.ends[pw.len() - 1] - pw.period).abs() < 1e-12);
        // The warm prefix energies the engine's stepping table is built
        // from never decrease (powers are non-negative).
        let mut acc = 0.0f64;
        let mut last = 0.0f64;
        for i in 0..pw.len() {
            let p_out = booster.warm_output_power(pw.powers[i]);
            assert!(p_out >= 0.0);
            acc += p_out * (pw.ends[i] - pw.start(i));
            assert!(acc >= last, "prefix energy decreased at segment {i}");
            last = acc;
        }
        // Raw per-period energy equals the trace's total energy.
        assert!(
            (pw.energy_per_period() - trace.total_energy()).abs()
                <= 1e-9 * trace.total_energy().max(1e-12),
            "piecewise energy {} vs trace {}",
            pw.energy_per_period(),
            trace.total_energy()
        );
        // The piecewise view agrees with point sampling, wraps included.
        for _ in 0..20 {
            let t = g.f64_in(0.0..3.0).max(0.0) * pw.period;
            let (epoch, idx) = pw.locate(t);
            let seg_start = epoch as f64 * pw.period + pw.start(idx);
            let seg_end = epoch as f64 * pw.period + pw.ends[idx];
            assert!(
                seg_start <= t + 1e-9 && t < seg_end + 1e-9,
                "locate({t}) gave [{seg_start}, {seg_end})"
            );
            // Sample strictly inside the segment (boundaries belong to
            // the next segment under floor indexing).
            let mid = 0.5 * (seg_start.max(t) + seg_end);
            assert_eq!(h.power_at(mid), pw.powers[idx], "t={t} idx={idx}");
        }
    });
}
