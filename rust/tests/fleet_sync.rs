//! Acceptance gates for the multi-device fleet layer.
//!
//! Three contracts, mirroring the repo's other sweep suites:
//!
//! * **Merge-order independence** — the delta-sync replicas are a
//!   delta-state CRDT: any pairwise exchange schedule that reaches
//!   version-vector closure converges every replica to the identical
//!   state (and fingerprint), regardless of the order meetings happened.
//! * **Determinism** — fleet sweeps are bitwise identical across worker
//!   pools {1, 2, 8} and on both integrator legs (the fleet simulation
//!   never touches the device engine, so the legs must agree with each
//!   other too).
//! * **Streaming/store parity** — fleet grids stream to the same bytes
//!   as the batch path, survive a mid-sweep kill, and resume from the
//!   store without re-simulating committed cells.

use aic::coordinator::scenario::{builtin, DeviceSpec, Projection, Scenario};
use aic::coordinator::sink::{emit_all, MemorySink, TableData};
use aic::coordinator::store::Store;
use aic::coordinator::stream::{run_streaming, StreamOptions};
use aic::coordinator::sync::{exchange, Replica};
use aic::exec::engine::EngineKind;
use aic::util::json;
use std::path::PathBuf;

const KINDS: [EngineKind; 2] = [EngineKind::Analytic, EngineKind::FixedStep];

// ---------------------------------------------------------------------
// Merge-order independence.
// ---------------------------------------------------------------------

/// A fixed workload of concurrent writes: every replica touches shared
/// rows (forcing tiebreaks), its own rows, and re-writes a shared
/// aggregate column several times (forcing version dominance).
fn seed_writes(fleet: &mut [Replica]) {
    let n = fleet.len();
    for (i, r) in fleet.iter_mut().enumerate() {
        for w in 0..4u32 {
            r.write(w, 0, (i as f64 + 1.0) * 0.125 + w as f64);
            r.write(w, 1, 1.0);
        }
        r.write(100 + i as u32, 0, i as f64);
        for round in 0..3u64 {
            r.write(u32::MAX, 2, (round * n as u64 + i as u64) as f64);
        }
    }
}

/// Run one exchange schedule (a list of (i, j) meetings) on a fresh
/// fleet and return the converged state + fingerprints. The schedule
/// must reach closure: every replica ends bitwise equal to replica 0.
fn run_schedule(n: usize, schedule: &[(usize, usize)]) -> (Vec<(u64, Vec<u8>)>, usize) {
    let mut fleet: Vec<Replica> = (0..n).map(|i| Replica::new(i, n)).collect();
    seed_writes(&mut fleet);
    for &(i, j) in schedule {
        assert_ne!(i, j);
        let (lo, hi) = fleet.split_at_mut(i.max(j));
        exchange(&mut lo[i.min(j)], &mut hi[0]);
    }
    let states: Vec<(u64, Vec<u8>)> = fleet
        .iter()
        .map(|r| {
            (r.fingerprint(), format!("{:?}{:?}", r.state(), r.vv()).into_bytes())
        })
        .collect();
    for (i, s) in states.iter().enumerate() {
        assert_eq!(s, &states[0], "replica {i} did not converge under {schedule:?}");
    }
    let residue = fleet.iter().map(|r| r.log_entries()).sum();
    (states, residue)
}

#[test]
fn any_exchange_schedule_converges_to_the_same_state() {
    let n = 4;
    // Three structurally different closures of the same write set:
    // a ring swept twice, a star through replica 0, and a "gossip storm"
    // that hits every pair in both orders.
    let ring: Vec<(usize, usize)> =
        (0..2 * n).map(|k| (k % n, (k + 1) % n)).collect();
    let star: Vec<(usize, usize)> = (1..n)
        .map(|i| (0, i))
        .chain((1..n).map(|i| (i, 0)))
        .chain((1..n).map(|i| (0, i)))
        .collect();
    let mut storm: Vec<(usize, usize)> = Vec::new();
    for round in 0..3 {
        for i in 0..n {
            for j in (i + 1)..n {
                if round % 2 == 0 {
                    storm.push((i, j));
                } else {
                    storm.push((j, i));
                }
            }
        }
    }
    let (want, _) = run_schedule(n, &ring);
    for (label, schedule) in [("star", &star), ("storm", &storm)] {
        let (got, _) = run_schedule(n, schedule);
        assert_eq!(got, want, "{label} schedule diverged from the ring closure");
    }
    // GC is coordination-free but still complete: once every pair has
    // gossiped twice more, every log entry is acknowledged everywhere
    // and pruned — no unbounded growth.
    let full: Vec<(usize, usize)> = storm.iter().chain(storm.iter()).copied().collect();
    let (got, residue) = run_schedule(n, &full);
    assert_eq!(got, want, "extra gossip changed the converged state");
    assert_eq!(residue, 0, "fully acknowledged logs must be pruned");
}

// ---------------------------------------------------------------------
// Sweep determinism across pools and engine legs.
// ---------------------------------------------------------------------

/// The `fleet_multi` builtin in fast mode: 6 devices with drop-out and
/// clock skew on the multi-source composite — the hardest deterministic
/// surface (every stochastic knob active), still CI-cheap at 600 s.
fn fleet_scenario(kind: EngineKind) -> Scenario {
    builtin("fleet_multi", 42)
        .unwrap()
        .with_devices(vec![DeviceSpec { engine: Some(kind), ..DeviceSpec::default() }])
        .resolve(true)
}

fn tables_with_workers(sc: &Scenario, workers: usize) -> Vec<TableData> {
    let run = sc.run_with(false, None, Some(workers));
    let mut m = MemorySink::new();
    emit_all(&run.tables(), &mut m).unwrap();
    m.tables
}

/// Every rendered byte of a table set, concatenated — so a formatting
/// drift cannot hide behind `PartialEq`.
fn render(tables: &[TableData]) -> String {
    let mut s = String::new();
    for t in tables {
        s.push_str(&t.stem);
        s.push_str(&t.to_csv());
        s.push_str(&t.to_markdown());
        s.push_str(&json::to_string(&t.to_json()));
    }
    s
}

#[test]
fn fleet_sweeps_are_bitwise_identical_across_pool_sizes_and_engines() {
    let mut legs: Vec<Vec<TableData>> = Vec::new();
    for kind in KINDS {
        let sc = fleet_scenario(kind);
        let reference = tables_with_workers(&sc, 1);
        for workers in [2usize, 8] {
            let got = tables_with_workers(&sc, workers);
            assert_eq!(got, reference, "{kind:?} workers={workers}: tables drifted");
            assert_eq!(
                render(&got),
                render(&reference),
                "{kind:?} workers={workers}: rendered bytes drifted"
            );
        }
        legs.push(reference);
    }
    // The fleet simulation never runs the device integrator, so the two
    // engine legs must agree on every result as well. Only the "device"
    // label column (which spells the engine override) may differ.
    let strip_device = |tables: &[TableData]| -> Vec<Vec<Vec<String>>> {
        tables
            .iter()
            .map(|t| {
                let col = t.header.iter().position(|h| h == "device");
                t.rows
                    .iter()
                    .map(|row| {
                        row.iter()
                            .enumerate()
                            .filter(|&(i, _)| Some(i) != col)
                            .map(|(_, c)| c.clone())
                            .collect()
                    })
                    .collect()
            })
            .collect()
    };
    assert_eq!(
        strip_device(&legs[0]),
        strip_device(&legs[1]),
        "engine legs disagree on fleet results"
    );
}

#[test]
fn every_fleet_projection_renders_on_both_builtins() {
    for name in ["fleet_solar", "fleet_multi"] {
        let base = builtin(name, 42).unwrap().resolve(true);
        for proj in [
            Projection::FleetLatency,
            Projection::FleetConvergence,
            Projection::FleetBytes,
            Projection::Cells,
        ] {
            let sc = base.clone().with_projection(proj);
            sc.validate().unwrap_or_else(|e| panic!("{name}/{proj:?}: {e}"));
            let tables = tables_with_workers(&sc, 2);
            assert!(!tables.is_empty(), "{name}/{proj:?}: no tables");
            for t in &tables {
                assert!(!t.rows.is_empty(), "{name}/{proj:?}: empty table {}", t.stem);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Streaming, kill/resume, and store dedup on a fleet grid.
// ---------------------------------------------------------------------

#[test]
fn fleet_streaming_matches_batch_and_resumes_to_identical_bytes() {
    let sc = fleet_scenario(EngineKind::Analytic);
    let cells = sc.plan().len();
    assert_eq!(cells, 2, "grid shape changed under this test");
    let cache = aic::coordinator::experiment::SupplyCache::new();
    let want = tables_with_workers(&sc, 2);

    // Store-less streaming equals batch for chunk shapes below,
    // unaligned to, and above the grid.
    for (workers, chunk) in [(1usize, 1usize), (2, 3), (8, 64)] {
        let opts = StreamOptions { workers: Some(workers), chunk, ..StreamOptions::default() };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, None, &mut m).unwrap();
        assert!(!report.partial);
        assert_eq!(report.ran, cells);
        assert_eq!(m.tables, want, "workers={workers} chunk={chunk}");
        assert_eq!(render(&m.tables), render(&want), "workers={workers} chunk={chunk}");
    }

    // Kill after 1 committed cell, reopen, resume to identical bytes.
    let path: PathBuf =
        std::env::temp_dir().join(format!("aic_fleet_resume_{}.aic", std::process::id()));
    let _ = std::fs::remove_file(&path);
    {
        let mut store = Store::open(&path).unwrap();
        let opts = StreamOptions {
            workers: Some(2),
            chunk: 1,
            stop_after: Some(1),
            ..StreamOptions::default()
        };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert!(report.partial, "stop_after must abort the sweep");
    }
    {
        let mut store = Store::open(&path).unwrap();
        assert_eq!(store.cell_count(), 1, "killed run must have committed 1 cell");
        let opts = StreamOptions { workers: Some(3), chunk: 5, ..StreamOptions::default() };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert!(!report.partial);
        assert_eq!(report.reused, 1, "committed fleet cells must not re-run");
        assert_eq!(report.ran, cells - 1);
        assert_eq!(m.tables, want, "resumed fleet projections drifted from the clean run");
        assert_eq!(render(&m.tables), render(&want));
    }
    // Everything committed: a re-run simulates nothing and still emits
    // the same bytes (the store round-trips the fleet digest payload).
    {
        let mut store = Store::open(&path).unwrap();
        let opts = StreamOptions { workers: Some(1), chunk: 64, ..StreamOptions::default() };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert_eq!(report.reused, cells);
        assert_eq!(report.ran, 0);
        assert_eq!(m.tables, want);
        assert_eq!(render(&m.tables), render(&want));
    }
    let _ = std::fs::remove_file(&path);
}
