//! Acceptance gates for the adaptive, environment-learning policy.
//!
//! Three contracts, mirroring the static-policy suites:
//!
//! * **Determinism** — adaptive sweeps are bitwise identical for any
//!   worker-pool size and on both integrator legs (`AIC_ENGINE`
//!   equivalents). The learner is deterministic UCB over a deterministic
//!   EWMA — no RNG — so there is nothing to tolerate.
//! * **Streaming/batch/resume equality** — the Pareto projection the
//!   adaptive builtins judge through streams to the same bytes as the
//!   batch path, survives a mid-sweep kill, and resumes from the store
//!   without re-simulating committed cells.
//! * **Pareto placement** — across the three synth families × three
//!   workloads (the `adaptive_*` builtins in fast mode), the adaptive
//!   policy lands on the static policies' accuracy/throughput frontier
//!   in at least two of the three judgements.

use aic::coordinator::experiment::{HarContext, SupplyCache};
use aic::coordinator::scenario::{
    builtin, DeviceSpec, HarvesterSpec, ParetoRow, Projection, Scenario, WorkloadSpec,
};
use aic::coordinator::sink::{emit_all, MemorySink, TableData};
use aic::coordinator::store::Store;
use aic::coordinator::stream::{run_streaming, StreamOptions};
use aic::energy::synth::SynthSpec;
use aic::exec::adaptive::{DEFAULT_ALPHA, DEFAULT_EXPLORE};
use aic::exec::engine::EngineKind;
use aic::exec::Policy;
use aic::util::json;
use std::path::PathBuf;

const KINDS: [EngineKind; 2] = [EngineKind::Analytic, EngineKind::FixedStep];

fn adaptive() -> Policy {
    Policy::Adaptive { alpha: DEFAULT_ALPHA, explore: DEFAULT_EXPLORE }
}

/// A small audio grid with the learner in the comparison set — cheap
/// enough to re-run under several pool shapes, rich enough to exercise
/// the predictor (bursty RF supply) and the bandit (6-probe menu).
fn audio_scenario(kind: EngineKind) -> Scenario {
    Scenario::new("adaptive_gate", WorkloadSpec::Audio)
        .with_harvesters(vec![HarvesterSpec::Synth(SynthSpec::builtin_rf())])
        .with_devices(vec![DeviceSpec { engine: Some(kind), ..DeviceSpec::default() }])
        .with_policies(vec![
            Policy::Continuous,
            Policy::Greedy,
            Policy::Smart { bound: 0.80 },
            adaptive(),
        ])
        .with_seeds(vec![1, 2])
        .with_horizon(600.0)
        .with_sample_period(30.0)
        .with_projection(Projection::Pareto)
}

fn tables_with_workers(sc: &Scenario, workers: usize, cache: &SupplyCache) -> Vec<TableData> {
    let run = sc.run_cached(false, None, Some(workers), cache);
    let mut m = MemorySink::new();
    emit_all(&run.tables(), &mut m).unwrap();
    m.tables
}

/// Every rendered byte of a table set, concatenated — so a formatting
/// drift cannot hide behind `PartialEq`.
fn render(tables: &[TableData]) -> String {
    let mut s = String::new();
    for t in tables {
        s.push_str(&t.stem);
        s.push_str(&t.to_csv());
        s.push_str(&t.to_markdown());
        s.push_str(&json::to_string(&t.to_json()));
    }
    s
}

/// The predictor the learner leans on must survive a hostile clock: a
/// cycle whose boot timestamp is non-finite invalidates the boot anchor,
/// so the next finite boot re-anchors instead of folding a two-cycle
/// span into the gap EWMA (the pre-fix behaviour doubled the estimate,
/// which halves the bandit's perceived duty cycle). Fully ignored
/// cycles must not count as "folded in" either.
#[test]
fn predictor_survives_a_hostile_clock_cycle() {
    use aic::energy::predictor::EwmaPredictor;
    let mut p = EwmaPredictor::new(0.3);
    p.observe(1.0e-3, 0.0);
    p.observe(1.0e-3, 5.0);
    assert!((p.gap_or(0.0) - 5.0).abs() < 1e-12);
    p.observe(1.0e-3, f64::NAN); // hostile clock, usable budget
    p.observe(1.0e-3, 15.0); // spans two cycles — must not fold
    assert!(
        (p.gap_or(0.0) - 5.0).abs() < 1e-12,
        "hostile-clock span inflated the gap to {}",
        p.gap_or(0.0)
    );
    p.observe(1.0e-3, 20.0); // learning resumes from the new anchor
    assert!((p.gap_or(0.0) - 5.0).abs() < 1e-12);
    assert_eq!(p.cycles_seen, 5, "the hostile cycle still folded its budget");
    p.observe(f64::NAN, f64::NAN); // nothing usable at all
    assert_eq!(p.cycles_seen, 5, "a fully ignored cycle must not count");
}

#[test]
fn adaptive_sweeps_are_bitwise_identical_across_pool_sizes_and_engines() {
    for kind in KINDS {
        let sc = audio_scenario(kind);
        let cache = SupplyCache::new();
        let reference = tables_with_workers(&sc, 1, &cache);
        for workers in [2usize, 8] {
            let got = tables_with_workers(&sc, workers, &cache);
            assert_eq!(got, reference, "{kind:?} workers={workers}: tables drifted");
            assert_eq!(
                render(&got),
                render(&reference),
                "{kind:?} workers={workers}: rendered bytes drifted"
            );
        }
    }
}

#[test]
fn pareto_projection_streams_and_resumes_to_identical_bytes() {
    let sc = audio_scenario(EngineKind::Analytic);
    let cells = sc.plan().len();
    assert_eq!(cells, 8, "grid shape changed under this test");
    let cache = SupplyCache::new();
    let want = tables_with_workers(&sc, 2, &cache);

    // Store-less streaming equals batch, for chunk shapes below,
    // unaligned to, and above the grid.
    for (workers, chunk) in [(1usize, 1usize), (2, 3), (3, 64)] {
        let opts = StreamOptions { workers: Some(workers), chunk, ..StreamOptions::default() };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, None, &mut m).unwrap();
        assert!(!report.partial);
        assert_eq!(report.ran, cells);
        assert_eq!(m.tables, want, "workers={workers} chunk={chunk}");
        assert_eq!(render(&m.tables), render(&want), "workers={workers} chunk={chunk}");
    }

    // Kill after 3 committed cells, reopen, resume to identical bytes.
    let path: PathBuf =
        std::env::temp_dir().join(format!("aic_adaptive_resume_{}.aic", std::process::id()));
    let _ = std::fs::remove_file(&path);
    {
        let mut store = Store::open(&path).unwrap();
        let opts = StreamOptions {
            workers: Some(2),
            chunk: 2,
            stop_after: Some(3),
            ..StreamOptions::default()
        };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert!(report.partial, "stop_after must abort the sweep");
    }
    {
        let mut store = Store::open(&path).unwrap();
        assert_eq!(store.cell_count(), 3, "killed run must have committed 3 cells");
        let opts = StreamOptions { workers: Some(3), chunk: 5, ..StreamOptions::default() };
        let mut m = MemorySink::new();
        let report = run_streaming(&sc, &opts, None, &cache, Some(&mut store), &mut m).unwrap();
        assert!(!report.partial);
        assert_eq!(report.reused, 3, "committed cells must not re-run");
        assert_eq!(report.ran, cells - 3);
        assert_eq!(m.tables, want, "resumed projections drifted from the clean run");
        assert_eq!(render(&m.tables), render(&want));
    }
    let _ = std::fs::remove_file(&path);
}

/// One judged builtin in fast mode: its Pareto rows plus basic table
/// shape checks (one row per policy, exactly one pick, pick is a
/// harvesting policy on the frontier).
fn judged_rows(name: &str, ctx: Option<&HarContext>) -> Vec<ParetoRow> {
    let sc = builtin(name, 42).unwrap().resolve(true);
    assert_eq!(sc.projection, Projection::Pareto, "{name}");
    let run = sc.run_with(false, ctx, None);
    let rows = run.pareto_rows();
    assert_eq!(rows.len(), sc.policies.len(), "{name}: one row per policy");
    let picks: Vec<&ParetoRow> = rows.iter().filter(|r| r.pick).collect();
    assert_eq!(picks.len(), 1, "{name}: exactly one auto-selection");
    assert!(picks[0].harvesting, "{name}: the pick must be a harvesting policy");
    assert!(picks[0].frontier, "{name}: the pick must sit on the frontier");
    assert!(
        rows.iter().any(|r| !r.harvesting && !r.frontier),
        "{name}: the continuous ceiling is shown but never on the frontier"
    );
    rows
}

#[test]
fn adaptive_reaches_the_static_frontier_on_most_judgements() {
    // The three synth families × three workloads, each judged in fast
    // mode. The learner must land on (or above) the static policies'
    // accuracy/throughput frontier in at least two of the three — the
    // Approxify claim: auto-tuning matches hand-picked settings without
    // per-deployment profiling.
    let multi = builtin("adaptive_multi", 42).unwrap().resolve(true);
    let ctx = multi.har_context();
    let mut on_frontier = 0;
    for (name, ctx) in [
        ("adaptive_solar", None),
        ("adaptive_rf", None),
        ("adaptive_multi", Some(&ctx)),
    ] {
        let rows = judged_rows(name, ctx);
        let ad = rows
            .iter()
            .find(|r| matches!(r.policy, Policy::Adaptive { .. }))
            .unwrap_or_else(|| panic!("{name}: adaptive row missing"));
        assert!(ad.harvesting, "{name}: adaptive is a harvesting policy");
        assert!(
            ad.accuracy >= 0.0 && ad.throughput >= 0.0,
            "{name}: degenerate adaptive point"
        );
        if ad.frontier {
            on_frontier += 1;
        }
    }
    assert!(
        on_frontier >= 2,
        "adaptive dominated in {} of 3 judgements — the learner should \
         reach the static frontier on at least two",
        3 - on_frontier
    );
}
