//! Panic-safety fuzz pass for the scenario JSON parser.
//!
//! `aic sweep` feeds user-supplied files straight into
//! `Scenario::parse`; nothing a file contains may panic (or overflow the
//! stack) — malformed input must come back as `Err`. This suite feeds
//! the parser truncations and byte-level mutations of every committed
//! `examples/scenarios/*.json` (which now includes the embedded-synth
//! grids), hand-built type-swaps, NaN/Inf number literals, and hostile
//! deep nesting. Whenever a mutation happens to still parse, the plan
//! expansion and validation must not panic either. Standalone
//! `SynthSpec` documents (`aic simulate --supply synth:<file>`) get the
//! same treatment, with the extra guarantee that any spec that parses
//! builds an environment with finite, non-negative powers.

use aic::coordinator::scenario::Scenario;
use aic::energy::synth::SynthSpec;
use aic::util::rng::Rng;

fn committed_examples() -> Vec<(String, String)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios");
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).expect("examples/scenarios missing") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            out.push((
                path.display().to_string(),
                std::fs::read_to_string(&path).unwrap(),
            ));
        }
    }
    assert!(!out.is_empty(), "no committed example scenarios found");
    out
}

/// Exercise the whole user-facing pipeline on arbitrary text: parse,
/// and — when the text happens to be a valid scenario — plan/resolve.
/// Returns whether parsing succeeded. Panics propagate and fail the
/// test: that is the property under test.
fn probe(text: &str) -> bool {
    match Scenario::parse(text) {
        Ok(sc) => {
            let _ = sc.validate();
            let _ = sc.plan().len();
            let _ = sc.resolve(true).plan().len();
            true
        }
        Err(e) => {
            assert!(!e.is_empty(), "empty error message");
            false
        }
    }
}

#[test]
fn truncations_of_committed_scenarios_error_cleanly() {
    for (path, text) in committed_examples() {
        assert!(probe(&text), "{path} stopped parsing");
        // Any truncation that cuts the document's closing brace is
        // malformed; beyond it only trailing whitespace is shaved off,
        // which must keep parsing.
        let close = text.rfind('}').expect("scenario documents are objects");
        for len in 0..text.len() {
            if !text.is_char_boundary(len) {
                continue;
            }
            if len <= close {
                assert!(
                    !probe(&text[..len]),
                    "{path}: truncation to {len} bytes still parsed"
                );
            } else {
                assert!(
                    probe(&text[..len]),
                    "{path}: shaving trailing whitespace at {len} broke parsing"
                );
            }
        }
    }
}

#[test]
fn byte_mutations_of_committed_scenarios_never_panic() {
    let replacements: &[u8] = b"{}[]\",:x0-\x00\xff";
    for (_path, text) in committed_examples() {
        let bytes = text.as_bytes();
        let mut rng = Rng::new(0xF022);
        for i in 0..bytes.len() {
            for &r in replacements {
                let mut mutated = bytes.to_vec();
                mutated[i] = r;
                if let Ok(s) = String::from_utf8(mutated) {
                    probe(&s); // must not panic; Ok or Err both fine
                }
            }
            // A few random splices (insert/delete) per position.
            let mut spliced = bytes.to_vec();
            let at = rng.index(spliced.len());
            if rng.chance(0.5) {
                spliced.insert(at, *rng.choose(replacements));
            } else {
                spliced.remove(at);
            }
            if let Ok(s) = String::from_utf8(spliced) {
                probe(&s);
            }
        }
    }
}

#[test]
fn type_swaps_are_errors_not_panics() {
    let cases = [
        // Wrong scalar types in every top-level slot.
        r#"{"name": 7, "workload": "har"}"#,
        r#"{"name": "x", "workload": 3}"#,
        r#"{"name": "x", "workload": "har", "horizon": "900"}"#,
        r#"{"name": "x", "workload": "har", "sample_period": []}"#,
        r#"{"name": "x", "workload": "har", "policies": "greedy"}"#,
        r#"{"name": "x", "workload": "har", "policies": [42]}"#,
        r#"{"name": "x", "workload": "har", "harvesters": [null]}"#,
        r#"{"name": "x", "workload": "har", "devices": "paper"}"#,
        r#"{"name": "x", "workload": "har", "devices": [42]}"#,
        r#"{"name": "x", "workload": "har", "devices": [{"capacitance": true}]}"#,
        r#"{"name": "x", "workload": "har", "seeds": [1.5]}"#,
        r#"{"name": "x", "workload": "har", "seeds": [-1]}"#,
        r#"{"name": "x", "workload": "har", "seeds": 1}"#,
        r#"{"name": "x", "workload": "har", "training": []}"#,
        r#"{"name": "x", "workload": "har", "training": {"windows": "six"}}"#,
        r#"{"name": "x", "workload": "har", "fast": {"horizon": {}}}"#,
        r#"{"name": "x", "workload": "har", "projection": 9}"#,
        r#"{"name": "x", "workload": "audio", "projection": "img-latency"}"#,
        // Workload objects with swapped field types.
        r#"{"name": "x", "workload": {"kind": "perforation", "size": "big", "skips": [0.1]}}"#,
        r#"{"name": "x", "workload": {"kind": "accuracy-curve", "ps": [true]}}"#,
        // The whole document is the wrong shape.
        r#"[]"#,
        r#""har""#,
        r#"42"#,
        r#"null"#,
    ];
    for text in cases {
        assert!(!probe(text), "accepted: {text}");
    }
}

#[test]
fn non_finite_number_literals_are_rejected() {
    for lit in ["NaN", "nan", "Infinity", "-Infinity", "1e999", "-1e999", "1e400"] {
        let doc = format!(r#"{{"name": "x", "workload": "har", "horizon": {lit}}}"#);
        assert!(!probe(&doc), "accepted horizon {lit}");
        let seeds = format!(r#"{{"name": "x", "workload": "har", "seeds": [{lit}]}}"#);
        assert!(!probe(&seeds), "accepted seed {lit}");
    }
}

/// Parse a candidate synth spec; when it parses, build one environment
/// and enforce the no-panic / no-infinity contract. Building is capped
/// per call site — mutated durations can legitimately grow the pattern.
fn probe_synth(text: &str, builds_left: &mut usize) -> bool {
    match SynthSpec::parse(text) {
        Ok(spec) => {
            if *builds_left > 0 {
                *builds_left -= 1;
                let pw = spec.build(1);
                assert!(
                    pw.powers.iter().all(|&p| p.is_finite() && p >= 0.0),
                    "mutated spec built a non-finite or negative power"
                );
            }
            true
        }
        Err(e) => {
            assert!(!e.is_empty(), "empty error message");
            false
        }
    }
}

#[test]
fn synth_spec_truncations_error_cleanly() {
    let text = SynthSpec::builtin_multi().to_json_string();
    let mut builds = 1usize;
    assert!(probe_synth(&text, &mut builds), "builtin multi spec stopped parsing");
    let close = text.rfind('}').expect("synth documents are objects");
    for len in 0..text.len() {
        if !text.is_char_boundary(len) {
            continue;
        }
        let mut builds = 0usize;
        if len <= close {
            assert!(
                !probe_synth(&text[..len], &mut builds),
                "truncation to {len} bytes still parsed"
            );
        }
    }
}

#[test]
fn synth_spec_byte_mutations_never_panic_or_emit_infinities() {
    let replacements: &[u8] = b"{}[]\",:x09-.e\x00";
    for spec in [SynthSpec::builtin_rf(), SynthSpec::builtin_multi()] {
        let text = spec.to_json_string();
        let bytes = text.as_bytes();
        let mut rng = Rng::new(0x5F2A);
        // Cap environment builds: most mutations fail to parse, but a
        // digit flip can survive and drive generation — a bounded sample
        // of those is enough to assert the finite-power contract.
        let mut builds = 64usize;
        for i in 0..bytes.len() {
            for &r in replacements {
                let mut mutated = bytes.to_vec();
                mutated[i] = r;
                if let Ok(s) = String::from_utf8(mutated) {
                    probe_synth(&s, &mut builds);
                }
            }
            let mut spliced = bytes.to_vec();
            let at = rng.index(spliced.len());
            if rng.chance(0.5) {
                spliced.insert(at, *rng.choose(replacements));
            } else {
                spliced.remove(at);
            }
            if let Ok(s) = String::from_utf8(spliced) {
                probe_synth(&s, &mut builds);
            }
        }
    }
}

#[test]
fn synth_spec_rejects_hostile_values() {
    let bad = [
        // NaN/Inf seeds and parameters are JSON-level errors.
        r#"{"name":"x","seed":NaN,"duration":60,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":Infinity,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":1e999,"combine":"sum","sources":[]}"#,
        // Fractional / negative seeds are type errors.
        r#"{"name":"x","seed":1.5,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        r#"{"name":"x","seed":-1,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        // Structural hostility: no sources, unknown combine, bad kind,
        // unknown keys, wrong shapes.
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"xor","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[{"kind":"fusion"}]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}],"extra":1}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":"rf"}"#,
        r#"[]"#,
        r#""synth""#,
        // Resource hostility: a segment budget far beyond the cap.
        r#"{"name":"x","seed":1,"duration":604800,"combine":"sum","sources":[{"kind":"thermal","base":0.0001,"amplitude":0.0003,"period":450,"env_dt":0.05,"noise":0}]}"#,
    ];
    let mut builds = 0usize;
    for text in bad {
        assert!(!probe_synth(text, &mut builds), "accepted: {text}");
    }
}

#[test]
fn hostile_nesting_errors_instead_of_overflowing_the_stack() {
    // A recursive-descent parser without a depth cap aborts on these
    // (stack overflow is not unwinding — the whole test binary dies).
    let bombs = [
        "[".repeat(200_000),
        "[".repeat(200_000) + &"]".repeat(200_000),
        "{\"a\":".repeat(120_000) + "1" + &"}".repeat(120_000),
        format!(
            r#"{{"name": "x", "workload": "har", "fast": {}1{}}}"#,
            "[".repeat(60_000),
            "]".repeat(60_000)
        ),
    ];
    for bomb in &bombs {
        assert!(!probe(bomb), "hostile nesting parsed");
    }
}
