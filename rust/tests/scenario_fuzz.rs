//! Panic-safety fuzz pass for the scenario JSON parser.
//!
//! `aic sweep` feeds user-supplied files straight into
//! `Scenario::parse`; nothing a file contains may panic (or overflow the
//! stack) — malformed input must come back as `Err`. This suite feeds
//! the parser truncations and byte-level mutations of every committed
//! `examples/scenarios/*.json` (which now includes the embedded-synth
//! grids), hand-built type-swaps, NaN/Inf number literals, and hostile
//! deep nesting. Whenever a mutation happens to still parse, the plan
//! expansion and validation must not panic either. Standalone
//! `SynthSpec` documents (`aic simulate --supply synth:<file>`) get the
//! same treatment, with the extra guarantee that any spec that parses
//! builds an environment with finite, non-negative powers.

use aic::coordinator::scenario::{Scenario, WorkloadSpec};
use aic::coordinator::store::{encode_record, grid_hash, CellDigest, Needs, Store};
use aic::energy::synth::SynthSpec;
use aic::util::json::{self, Value};
use aic::util::rng::Rng;
use std::path::PathBuf;

fn committed_examples() -> Vec<(String, String)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios");
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).expect("examples/scenarios missing") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            out.push((
                path.display().to_string(),
                std::fs::read_to_string(&path).unwrap(),
            ));
        }
    }
    assert!(!out.is_empty(), "no committed example scenarios found");
    out
}

/// Exercise the whole user-facing pipeline on arbitrary text: parse,
/// and — when the text happens to be a valid scenario — plan/resolve.
/// Returns whether parsing succeeded. Panics propagate and fail the
/// test: that is the property under test.
fn probe(text: &str) -> bool {
    match Scenario::parse(text) {
        Ok(sc) => {
            let _ = sc.validate();
            let _ = sc.plan().len();
            let _ = sc.resolve(true).plan().len();
            true
        }
        Err(e) => {
            assert!(!e.is_empty(), "empty error message");
            false
        }
    }
}

#[test]
fn truncations_of_committed_scenarios_error_cleanly() {
    for (path, text) in committed_examples() {
        assert!(probe(&text), "{path} stopped parsing");
        // Any truncation that cuts the document's closing brace is
        // malformed; beyond it only trailing whitespace is shaved off,
        // which must keep parsing.
        let close = text.rfind('}').expect("scenario documents are objects");
        for len in 0..text.len() {
            if !text.is_char_boundary(len) {
                continue;
            }
            if len <= close {
                assert!(
                    !probe(&text[..len]),
                    "{path}: truncation to {len} bytes still parsed"
                );
            } else {
                assert!(
                    probe(&text[..len]),
                    "{path}: shaving trailing whitespace at {len} broke parsing"
                );
            }
        }
    }
}

#[test]
fn byte_mutations_of_committed_scenarios_never_panic() {
    let replacements: &[u8] = b"{}[]\",:x0-\x00\xff";
    for (_path, text) in committed_examples() {
        let bytes = text.as_bytes();
        let mut rng = Rng::new(0xF022);
        for i in 0..bytes.len() {
            for &r in replacements {
                let mut mutated = bytes.to_vec();
                mutated[i] = r;
                if let Ok(s) = String::from_utf8(mutated) {
                    probe(&s); // must not panic; Ok or Err both fine
                }
            }
            // A few random splices (insert/delete) per position.
            let mut spliced = bytes.to_vec();
            let at = rng.index(spliced.len());
            if rng.chance(0.5) {
                spliced.insert(at, *rng.choose(replacements));
            } else {
                spliced.remove(at);
            }
            if let Ok(s) = String::from_utf8(spliced) {
                probe(&s);
            }
        }
    }
}

#[test]
fn type_swaps_are_errors_not_panics() {
    let cases = [
        // Wrong scalar types in every top-level slot.
        r#"{"name": 7, "workload": "har"}"#,
        r#"{"name": "x", "workload": 3}"#,
        r#"{"name": "x", "workload": "har", "horizon": "900"}"#,
        r#"{"name": "x", "workload": "har", "sample_period": []}"#,
        r#"{"name": "x", "workload": "har", "policies": "greedy"}"#,
        r#"{"name": "x", "workload": "har", "policies": [42]}"#,
        r#"{"name": "x", "workload": "har", "harvesters": [null]}"#,
        r#"{"name": "x", "workload": "har", "devices": "paper"}"#,
        r#"{"name": "x", "workload": "har", "devices": [42]}"#,
        r#"{"name": "x", "workload": "har", "devices": [{"capacitance": true}]}"#,
        r#"{"name": "x", "workload": "har", "seeds": [1.5]}"#,
        r#"{"name": "x", "workload": "har", "seeds": [-1]}"#,
        r#"{"name": "x", "workload": "har", "seeds": 1}"#,
        r#"{"name": "x", "workload": "har", "training": []}"#,
        r#"{"name": "x", "workload": "har", "training": {"windows": "six"}}"#,
        r#"{"name": "x", "workload": "har", "fast": {"horizon": {}}}"#,
        r#"{"name": "x", "workload": "har", "projection": 9}"#,
        r#"{"name": "x", "workload": "audio", "projection": "img-latency"}"#,
        // Workload objects with swapped field types.
        r#"{"name": "x", "workload": {"kind": "perforation", "size": "big", "skips": [0.1]}}"#,
        r#"{"name": "x", "workload": {"kind": "accuracy-curve", "ps": [true]}}"#,
        // The whole document is the wrong shape.
        r#"[]"#,
        r#""har""#,
        r#"42"#,
        r#"null"#,
    ];
    for text in cases {
        assert!(!probe(text), "accepted: {text}");
    }
}

/// Hostile fleet workload objects: every one must come back as `Err`
/// from `Scenario::parse` (or fail validation), never panic — the fleet
/// spec carries enough numeric knobs (device counts, overlap matrices,
/// skew) to make unchecked arithmetic or allocation a real hazard.
#[test]
fn hostile_fleet_specs_are_errors_not_panics() {
    let wrap = |workload: &str| format!(r#"{{"name": "x", "workload": {workload}}}"#);
    let bad = [
        // Degenerate and resource-hostile device counts.
        r#"{"kind": "fleet", "devices": 0}"#,
        r#"{"kind": "fleet", "devices": 1}"#,
        r#"{"kind": "fleet", "devices": 1000}"#,
        r#"{"kind": "fleet", "devices": 18446744073709551615}"#,
        r#"{"kind": "fleet", "devices": -4}"#,
        r#"{"kind": "fleet", "devices": 4.5}"#,
        r#"{"kind": "fleet", "devices": "four"}"#,
        // Periods and fractions out of range or non-finite.
        r#"{"kind": "fleet", "meeting_period": 0}"#,
        r#"{"kind": "fleet", "meeting_period": -15}"#,
        r#"{"kind": "fleet", "obs_period": 0}"#,
        r#"{"kind": "fleet", "obs_period": 1e999}"#,
        r#"{"kind": "fleet", "up_fraction": 0}"#,
        r#"{"kind": "fleet", "up_fraction": -0.5}"#,
        r#"{"kind": "fleet", "up_fraction": 101}"#,
        // Drop rates at or past certain loss, hostile skew.
        r#"{"kind": "fleet", "drop_rate": 1}"#,
        r#"{"kind": "fleet", "drop_rate": 1.5}"#,
        r#"{"kind": "fleet", "drop_rate": -0.1}"#,
        r#"{"kind": "fleet", "clock_skew": -1}"#,
        r#"{"kind": "fleet", "clock_skew": NaN}"#,
        r#"{"kind": "fleet", "clock_skew": 1e999}"#,
        // Overlap matrices: wrong shape, asymmetric, out of range,
        // wrong element types.
        r#"{"kind": "fleet", "devices": 3, "overlap": [[1, 1], [1, 1]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": [[1, 1], [1]]}"#,
        // Ragged beyond the transpose's reach: validation must reject
        // the shape before the symmetry check indexes row 2 column 1.
        r#"{"kind": "fleet", "devices": 3, "overlap": [[1, 1, 1], [1, 1, 1], [1]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": [[1, 0.2], [0.8, 1]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": [[1, 1.5], [1.5, 1]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": [[1, -0.5], [-0.5, 1]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": [["a", "b"], ["c", "d"]]}"#,
        r#"{"kind": "fleet", "devices": 2, "overlap": 1}"#,
        // Unknown keys and type-swapped fields are strict errors.
        r#"{"kind": "fleet", "sneaky": 1}"#,
        r#"{"kind": "fleet", "devices": {}}"#,
        r#"{"kind": "fleet", "drop_rate": "low"}"#,
    ];
    for workload in bad {
        let doc = wrap(workload);
        assert!(!probe(&doc), "accepted: {doc}");
    }
    // The well-formed baseline parses — the rejections above are real.
    assert!(probe(&wrap(r#"{"kind": "fleet", "devices": 3}"#)));
    // A fleet grid whose horizon implies a meeting count past the cap
    // must fail validation, not allocate.
    let flood = r#"{"name": "x", "workload": {"kind": "fleet", "devices": 64,
        "meeting_period": 0.001}, "horizon": 3600}"#;
    assert!(!probe(flood), "accepted a meeting-count flood");
    // Fleet workloads only fit the fleet/cells projections.
    let mismatch =
        r#"{"name": "x", "workload": {"kind": "fleet"}, "projection": "policy-accuracy"}"#;
    assert!(!probe(mismatch), "accepted a non-fleet projection on a fleet workload");
}

#[test]
fn non_finite_number_literals_are_rejected() {
    for lit in ["NaN", "nan", "Infinity", "-Infinity", "1e999", "-1e999", "1e400"] {
        let doc = format!(r#"{{"name": "x", "workload": "har", "horizon": {lit}}}"#);
        assert!(!probe(&doc), "accepted horizon {lit}");
        let seeds = format!(r#"{{"name": "x", "workload": "har", "seeds": [{lit}]}}"#);
        assert!(!probe(&seeds), "accepted seed {lit}");
    }
}

/// Parse a candidate synth spec; when it parses, build one environment
/// and enforce the no-panic / no-infinity contract. Building is capped
/// per call site — mutated durations can legitimately grow the pattern.
fn probe_synth(text: &str, builds_left: &mut usize) -> bool {
    match SynthSpec::parse(text) {
        Ok(spec) => {
            if *builds_left > 0 {
                *builds_left -= 1;
                let pw = spec.build(1);
                assert!(
                    pw.powers.iter().all(|&p| p.is_finite() && p >= 0.0),
                    "mutated spec built a non-finite or negative power"
                );
            }
            true
        }
        Err(e) => {
            assert!(!e.is_empty(), "empty error message");
            false
        }
    }
}

#[test]
fn synth_spec_truncations_error_cleanly() {
    let text = SynthSpec::builtin_multi().to_json_string();
    let mut builds = 1usize;
    assert!(probe_synth(&text, &mut builds), "builtin multi spec stopped parsing");
    let close = text.rfind('}').expect("synth documents are objects");
    for len in 0..text.len() {
        if !text.is_char_boundary(len) {
            continue;
        }
        let mut builds = 0usize;
        if len <= close {
            assert!(
                !probe_synth(&text[..len], &mut builds),
                "truncation to {len} bytes still parsed"
            );
        }
    }
}

#[test]
fn synth_spec_byte_mutations_never_panic_or_emit_infinities() {
    let replacements: &[u8] = b"{}[]\",:x09-.e\x00";
    for spec in [SynthSpec::builtin_rf(), SynthSpec::builtin_multi()] {
        let text = spec.to_json_string();
        let bytes = text.as_bytes();
        let mut rng = Rng::new(0x5F2A);
        // Cap environment builds: most mutations fail to parse, but a
        // digit flip can survive and drive generation — a bounded sample
        // of those is enough to assert the finite-power contract.
        let mut builds = 64usize;
        for i in 0..bytes.len() {
            for &r in replacements {
                let mut mutated = bytes.to_vec();
                mutated[i] = r;
                if let Ok(s) = String::from_utf8(mutated) {
                    probe_synth(&s, &mut builds);
                }
            }
            let mut spliced = bytes.to_vec();
            let at = rng.index(spliced.len());
            if rng.chance(0.5) {
                spliced.insert(at, *rng.choose(replacements));
            } else {
                spliced.remove(at);
            }
            if let Ok(s) = String::from_utf8(spliced) {
                probe_synth(&s, &mut builds);
            }
        }
    }
}

#[test]
fn synth_spec_rejects_hostile_values() {
    let bad = [
        // NaN/Inf seeds and parameters are JSON-level errors.
        r#"{"name":"x","seed":NaN,"duration":60,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":Infinity,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":1e999,"combine":"sum","sources":[]}"#,
        // Fractional / negative seeds are type errors.
        r#"{"name":"x","seed":1.5,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        r#"{"name":"x","seed":-1,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        // Structural hostility: no sources, unknown combine, bad kind,
        // unknown keys, wrong shapes.
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"xor","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[{"kind":"fusion"}]}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":[{"kind":"rf","burst_power":0.001,"mean_on":0.5,"mean_off":4.5,"jitter":0}],"extra":1}"#,
        r#"{"name":"x","seed":1,"duration":60,"combine":"sum","sources":"rf"}"#,
        r#"[]"#,
        r#""synth""#,
        // Resource hostility: a segment budget far beyond the cap.
        r#"{"name":"x","seed":1,"duration":604800,"combine":"sum","sources":[{"kind":"thermal","base":0.0001,"amplitude":0.0003,"period":450,"env_dt":0.05,"noise":0}]}"#,
    ];
    let mut builds = 0usize;
    for text in bad {
        assert!(!probe_synth(text, &mut builds), "accepted: {text}");
    }
}

// ---------------------------------------------------------------------
// Experiment-store files get the same hostility treatment: `aic sweep
// --store` and `aic store` open user-supplied files, so truncations,
// byte flips, duplicate/conflicting records, and hostile record lengths
// must come back as `Err` or a salvaged prefix — never a panic, an
// over-allocation, or a double-counted cell.
// ---------------------------------------------------------------------

fn store_tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("aic_fuzz_{tag}_{}.aic", std::process::id()))
}

fn fuzz_digest(seed: u64) -> CellDigest {
    CellDigest {
        emitted: 10 + seed,
        duration: 600.0,
        power_cycles: 2 * seed,
        power_failures: seed,
        app_energy: 1e-3,
        state_energy: 1e-4,
        quality_ok: seed,
        quality_total: 10 + seed,
        same_cycle: seed,
        steps_sum: 40 * seed,
        latency_sum: seed,
        latency_bins: None,
        slots: None,
        pictures: None,
        fleet: None,
    }
}

/// A small committed store (one experiment, cells 0/1/2) plus its hash.
fn seed_store(path: &PathBuf) -> u64 {
    let _ = std::fs::remove_file(path);
    let sc = Scenario::new("fuzz", WorkloadSpec::Audio);
    let hash = grid_hash(&sc, Needs::none());
    let mut st = Store::open(path).unwrap();
    st.ensure_experiment("fuzz", hash, &sc).unwrap();
    for i in 0..3u32 {
        assert!(st.append_cell(hash, i, &fuzz_digest(i as u64 + 1)).unwrap());
    }
    st.sync().unwrap();
    hash
}

/// The exact on-disk frame `append_cell` writes for `(hash, idx, d)` —
/// for crafting byte-identical duplicates and conflicting twins.
fn cell_frame(hash: u64, idx: u32, d: &CellDigest) -> Vec<u8> {
    let payload = Value::obj(vec![
        ("k", "cell".into()),
        ("hash", format!("{hash:016x}").as_str().into()),
        ("idx", (idx as f64).into()),
        ("d", d.to_json()),
    ]);
    encode_record(json::to_string(&payload).into_bytes().as_slice())
}

#[test]
fn store_truncations_salvage_a_prefix_or_error_cleanly() {
    let path = store_tmp("trunc");
    let hash = seed_store(&path);
    let bytes = std::fs::read(&path).unwrap();
    let cut_path = store_tmp("trunc_cut");
    for len in 0..bytes.len() {
        std::fs::write(&cut_path, &bytes[..len]).unwrap();
        match Store::open(&cut_path) {
            Ok(st) => {
                assert!(
                    len == 0 || len >= 8,
                    "{len}-byte file parsed as a store"
                );
                assert!(st.cell_count() <= 3, "truncation grew the cell count");
                assert!(st.cell_count_for(hash) <= 3);
            }
            Err(_) => {
                // Only a torn magic may refuse to open; past it every
                // truncation salvages the valid record prefix.
                assert!(len < 8, "truncation to {len} bytes refused to open");
            }
        }
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&cut_path);
}

#[test]
fn store_byte_flips_never_panic_or_double_count() {
    let path = store_tmp("flip");
    let hash = seed_store(&path);
    let bytes = std::fs::read(&path).unwrap();
    let flip_path = store_tmp("flip_mut");
    for i in 0..bytes.len() {
        for flip in [0x01u8, 0x80, 0xFF] {
            let mut mutated = bytes.clone();
            mutated[i] ^= flip;
            std::fs::write(&flip_path, &mutated).unwrap();
            match Store::open(&flip_path) {
                Ok(mut st) => {
                    assert!(i >= 8, "flipped magic byte {i} still opened");
                    // Whatever survived must be readable and ≤ the
                    // committed set — a flip can only shrink the prefix.
                    assert!(st.cell_count() <= 3);
                    for idx in st.cell_indices(hash) {
                        st.read_cell(hash, idx).unwrap().unwrap();
                    }
                }
                Err(_) => {
                    assert!(i < 8, "flip at {i} (past the magic) refused to open");
                }
            }
        }
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&flip_path);
}

#[test]
fn store_oversized_record_length_is_salvaged_without_allocating() {
    let path = store_tmp("oversize");
    let hash = seed_store(&path);
    // A torn tail whose length field claims 4 GiB: `open` must treat it
    // as garbage (MAX_RECORD guards the allocation) and keep the prefix.
    let mut bytes = std::fs::read(&path).unwrap();
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.extend_from_slice(&[0xAB; 4]);
    std::fs::write(&path, &bytes).unwrap();
    let st = Store::open(&path).unwrap();
    assert_eq!(st.cell_count_for(hash), 3);
    assert_eq!(st.salvaged_bytes(), 8);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn store_duplicate_and_conflicting_records_never_double_count() {
    let path = store_tmp("dup");
    let hash = seed_store(&path);
    // Append a byte-identical duplicate of cell 1 and a conflicting twin
    // of cell 2 — e.g. two racing writers sharing one store file.
    let mut bytes = std::fs::read(&path).unwrap();
    bytes.extend_from_slice(&cell_frame(hash, 1, &fuzz_digest(2)));
    bytes.extend_from_slice(&cell_frame(hash, 2, &fuzz_digest(99)));
    std::fs::write(&path, &bytes).unwrap();
    let mut st = Store::open(&path).unwrap();
    assert_eq!(st.cell_count_for(hash), 3, "re-appends must not add cells");
    assert_eq!(st.duplicates(), 1);
    assert_eq!(st.conflicts(), 1);
    // First record wins: the conflicting twin is never served.
    assert_eq!(st.read_cell(hash, 2).unwrap().unwrap(), fuzz_digest(3));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn store_self_heals_a_torn_tail_on_the_next_append() {
    let path = store_tmp("heal");
    let hash = seed_store(&path);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes.extend_from_slice(&[0x77; 11]); // torn frame
    std::fs::write(&path, &bytes).unwrap();
    {
        let mut st = Store::open(&path).unwrap();
        assert_eq!(st.salvaged_bytes(), 11);
        assert!(st.append_cell(hash, 7, &fuzz_digest(7)).unwrap());
        st.sync().unwrap();
    }
    let mut st = Store::open(&path).unwrap();
    assert_eq!(st.salvaged_bytes(), 0, "append must truncate the torn tail");
    assert_eq!(st.cell_count_for(hash), 4);
    assert_eq!(st.read_cell(hash, 7).unwrap().unwrap(), fuzz_digest(7));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn hostile_nesting_errors_instead_of_overflowing_the_stack() {
    // A recursive-descent parser without a depth cap aborts on these
    // (stack overflow is not unwinding — the whole test binary dies).
    let bombs = [
        "[".repeat(200_000),
        "[".repeat(200_000) + &"]".repeat(200_000),
        "{\"a\":".repeat(120_000) + "1" + &"}".repeat(120_000),
        format!(
            r#"{{"name": "x", "workload": "har", "fast": {}1{}}}"#,
            "[".repeat(60_000),
            "]".repeat(60_000)
        ),
    ];
    for bomb in &bombs {
        assert!(!probe(bomb), "hostile nesting parsed");
    }
}
