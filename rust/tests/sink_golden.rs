//! Golden-file tests for `coordinator/sink.rs`: the exact bytes each
//! sink emits for a fixed sweep table are committed under
//! `rust/tests/golden/` and asserted byte-for-byte, so any formatting
//! drift (markdown layout, CSV quoting, JSON pretty-printing, trailing
//! newlines) fails loudly instead of silently changing every artifact
//! consumers parse.
//!
//! The table is a fixed miniature of a sweep's cells view (harvester /
//! policy / quality / note) with cells chosen to exercise the quoting
//! paths: a comma cell, a double-quote cell, and the `pct`/`f2`
//! formatting helpers on exactly-representable values — deliberately
//! *not* a live campaign, so the goldens pin the sink layer alone and
//! never move when simulation numerics do.
//!
//! The `golden_synth_*` files extend the same contract to the synthetic
//! environment generator: the spec-JSON bytes of the builtin
//! `synth_multi` family (schema drift detector) and a hand-computable
//! composite-merge segment table (compose-layer drift detector). Both
//! use exactly-representable inputs, so the committed bytes are stable
//! across platforms.
//!
//! Regenerating after an intentional format change:
//!
//! ```text
//! AIC_BLESS=1 cargo test --test sink_golden
//! ```
//!
//! then commit the rewritten files under `rust/tests/golden/`.

use aic::coordinator::sink::{f2, pct, CsvSink, JsonSink, MarkdownSink, Sink, TableData};
use aic::energy::synth::{merge, Combine, SynthSpec};
use aic::energy::traces::Piecewise;

fn golden_dir() -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden")).to_path_buf()
}

fn fixed_table() -> TableData {
    let mut t = TableData::new(
        "golden_sweep",
        "Golden sweep - sink formatting contract",
        &["harvester", "policy", "quality", "note"],
    );
    t.push(vec!["kinetic".into(), "greedy".into(), pct(0.5), "plain".into()]);
    t.push(vec![
        "RF".into(),
        "smart80".into(),
        pct(0.875),
        "comma, separated".into(),
    ]);
    t.push(vec![
        "SOM".into(),
        "chinchilla".into(),
        f2(1.25),
        "has \"quotes\"".into(),
    ]);
    t
}

/// Compare `got` against the committed golden, or rewrite the golden
/// under `AIC_BLESS=1`.
fn check(name: &str, got: &[u8]) {
    let path = golden_dir().join(name);
    if std::env::var("AIC_BLESS").is_ok() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, got).unwrap();
        return;
    }
    let want = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("missing golden {} ({e}); regenerate with AIC_BLESS=1", path.display())
    });
    assert_eq!(
        got,
        &want[..],
        "{name} drifted from the committed golden;\n--- got ---\n{}\n--- want ---\n{}\n\
         (if the change is intentional, regenerate with AIC_BLESS=1)",
        String::from_utf8_lossy(got),
        String::from_utf8_lossy(&want),
    );
}

/// The compose-layer golden table: two hand-written source patterns
/// (exact binary fractions of a milliwatt on integer boundaries) pushed
/// through every combinator. Each cell is exactly representable, so the
/// rendered bytes pin the merge semantics — union boundaries, sum/max
/// arithmetic, switchover efficiency scaling — without depending on any
/// platform-sensitive rounding.
fn synth_compose_table() -> TableData {
    let a = Piecewise {
        ends: vec![2.0, 6.0, 10.0],
        powers: vec![1.0e-3, 0.0, 2.0e-3],
        period: 10.0,
    };
    let b = Piecewise { ends: vec![5.0, 10.0], powers: vec![0.5e-3, 1.5e-3], period: 10.0 };
    let sum = merge(&[a.clone(), b.clone()], Combine::Sum, 1.0, 10.0);
    let max = merge(&[a.clone(), b.clone()], Combine::Max, 1.0, 10.0);
    let sw = merge(&[a, b], Combine::Switchover, 0.5, 10.0);
    assert_eq!(sum.ends, max.ends);
    assert_eq!(sum.ends, sw.ends);
    let mut t = TableData::new(
        "golden_synth_compose",
        "Synth compose layer - segment contract",
        &["start_s", "end_s", "sum_uW", "max_uW", "switchover_uW"],
    );
    for i in 0..sum.len() {
        t.push(vec![
            format!("{:.1}", sum.start(i)),
            format!("{:.1}", sum.ends[i]),
            format!("{:.3}", sum.powers[i] * 1e6),
            format!("{:.3}", max.powers[i] * 1e6),
            format!("{:.3}", sw.powers[i] * 1e6),
        ]);
    }
    t
}

#[test]
fn synth_compose_matches_goldens() {
    let t = synth_compose_table();
    check("golden_synth_compose.md", (t.to_markdown() + "\n").as_bytes());
    check("golden_synth_compose.csv", t.to_csv().as_bytes());
    check(
        "golden_synth_compose.json",
        aic::util::json::to_string_pretty(&t.to_json()).as_bytes(),
    );
}

#[test]
fn synth_multi_spec_json_matches_golden() {
    // The committed spec bytes of the `synth_multi` builtin family: any
    // schema change (field rename, serialisation order, number
    // formatting) or parameter drift in the builtin is byte-detectable,
    // and the golden itself must parse back to the identical spec.
    let spec = SynthSpec::builtin_multi();
    let text = spec.to_json_string();
    check("golden_synth_multi_spec.json", text.as_bytes());
    let back = SynthSpec::parse(&text).expect("builtin spec round-trips");
    assert_eq!(back, spec);
}

#[test]
fn markdown_sink_matches_golden() {
    let t = fixed_table();
    let mut buf = Vec::new();
    MarkdownSink::new(&mut buf).table(&t).unwrap();
    check("golden_sweep.md", &buf);
    // The streamed sink and the buffered renderer stay in lock-step.
    assert_eq!(String::from_utf8(buf).unwrap(), t.to_markdown() + "\n");
}

#[test]
fn csv_sink_matches_golden() {
    let t = fixed_table();
    let dir = std::env::temp_dir().join("aic_sink_golden_csv");
    let _ = std::fs::remove_dir_all(&dir);
    CsvSink::new(dir.to_str().unwrap()).table(&t).unwrap();
    let got = std::fs::read(dir.join("golden_sweep.csv")).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    check("golden_sweep.csv", &got);
    // The file path and the in-memory renderer agree.
    assert_eq!(String::from_utf8(got).unwrap(), t.to_csv());
}

#[test]
fn json_sink_matches_golden() {
    let t = fixed_table();
    let dir = std::env::temp_dir().join("aic_sink_golden_json");
    let _ = std::fs::remove_dir_all(&dir);
    JsonSink::new(dir.to_str().unwrap()).table(&t).unwrap();
    let got = std::fs::read(dir.join("golden_sweep.json")).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    check("golden_sweep.json", &got);
    // The golden is also well-formed JSON that round-trips to the table.
    let v = aic::util::json::parse(std::str::from_utf8(&got).unwrap()).unwrap();
    assert_eq!(v, t.to_json());
}
