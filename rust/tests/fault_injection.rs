//! Intermittence fault-injection correctness suite.
//!
//! Every shipping runtime — continuous, Chinchilla, Alpaca, GREEDY,
//! SMART and ADAPTIVE — is driven through [`run_checked`]: the program is wrapped in
//! a [`TrackedProgram`] shadow, the engine is armed with a [`FaultPlan`],
//! and the resulting totally-ordered trace is checked for WAR-hazard
//! freedom, replay idempotence, monotone commit and volatility
//! discipline. Two fault regimes gate every (runtime, workload, engine)
//! cell:
//!
//! * **Exhaustive enumeration** — one campaign per op ordinal in the
//!   fault-free run's `0..ops` fault-point space, so every reachable
//!   cycle boundary (mid-step, between execute and commit, during emit,
//!   during restore) is forced exactly once.
//! * **Randomized schedules** — `AIC_FAULT_SEEDS` (default 200) seeded
//!   Bernoulli schedules per cell, bitwise reproducible by seed.
//!
//! The mutation-gate tests (`mutation_gate_*`, selected by name in CI)
//! prove the harness has teeth: each deliberately broken runtime in
//! [`aic::exec::mutants`] must be flagged with its expected violation
//! kind, while the shipping counterpart stays clean under the same
//! schedules.

use std::sync::OnceLock;

use aic::audio::app::{self as audio_app, AudioOutput, AudioProgram, AudioSource};
use aic::audio::detector::SpectralDetector;
use aic::audio::stream::labelled_windows;
use aic::energy::estimator::{EnergyProfile, SmartTable};
use aic::energy::harvester::Harvester;
use aic::energy::mcu::{McuModel, OpCost};
use aic::exec::alpaca::{AlpacaConfig, AlpacaRuntime};
use aic::exec::engine::{Engine, EngineConfig, EngineKind};
use aic::exec::mutants::{
    EarlyCommitAlpacaRuntime, EmitBeforeCommitRuntime, NoWarChinchillaRuntime,
    PersistentGreedyRuntime,
};
use aic::exec::adaptive::STATE_WORDS;
use aic::exec::program::SyntheticProgram;
use aic::exec::{
    alpaca, approx, chinchilla, run_checked, CheckedRun, FaultPlan, Policy, RuntimeSpec,
    TrackedProgram,
};
use aic::har::app::{HarOutput, HarProgram, WindowSource};
use aic::har::dataset::{Corpus, CorpusSpec, LabelledWindow};
use aic::imgproc::app::{CornerOutput, CornerProgram};
use aic::imgproc::harris::HarrisConfig;
use aic::svm::anytime::AnytimeSvm;
use aic::svm::train::{train_ovr, TrainConfig};
use aic::util::testkit::{assert_no_violations, fault_seeds};

const PERIOD: f64 = 60.0;
const POWER: f64 = 2.0e-3;
const KINDS: [EngineKind; 2] = [EngineKind::Analytic, EngineKind::FixedStep];

/// Both engine legs are exercised explicitly (the `AIC_ENGINE` variable
/// only picks the default); the CI matrix re-runs the suite under each
/// leg anyway so the per-leg jobs stay comparable with the other suites.
fn harvesting(kind: EngineKind, horizon: f64) -> Engine {
    let mut cfg = EngineConfig::paper_default(horizon);
    cfg.kind = kind;
    Engine::new(cfg, Harvester::Constant(POWER))
}

fn kind_name(kind: EngineKind) -> &'static str {
    match kind {
        EngineKind::Analytic => "analytic",
        EngineKind::FixedStep => "step",
    }
}

// ---------------------------------------------------------------------
// Synthetic workload: the dense fault-point space every policy shares.
// ---------------------------------------------------------------------

const SYN_INPUTS: u64 = 2;
const SYN_STEPS: usize = 8;
const SYN_CYCLES: u64 = 20_000;
const SYN_HORIZON: f64 = 600.0;

fn synthetic_policies() -> Vec<Policy> {
    vec![
        Policy::Continuous,
        Policy::Chinchilla,
        Policy::Alpaca,
        Policy::Greedy,
        Policy::Smart { bound: 0.60 },
        Policy::Adaptive { alpha: 0.2, explore: 0.5 },
    ]
}

/// SMART table for the synthetic program: linear accuracy from chance
/// to 0.9 over the step count (same shape as `tests/policy_matrix.rs`).
fn synthetic_table() -> SmartTable {
    let mcu = McuModel::paper_default();
    let costs: Vec<OpCost> = (0..SYN_STEPS).map(|_| OpCost::cycles(SYN_CYCLES)).collect();
    let profile = EnergyProfile::from_costs(&mcu, &costs);
    let acc: Vec<f64> = (0..=SYN_STEPS)
        .map(|p| 1.0 / 6.0 + (0.9 - 1.0 / 6.0) * p as f64 / SYN_STEPS as f64)
        .collect();
    let emit = mcu.energy(&OpCost { cycles: 500, ble_bytes: 1, ..Default::default() });
    SmartTable::new(acc, &profile, emit)
}

fn checked_synthetic(policy: Policy, kind: EngineKind, plan: FaultPlan) -> CheckedRun<usize> {
    let program = SyntheticProgram::new(SYN_INPUTS, SYN_STEPS, SYN_CYCLES);
    // The continuous baseline runs on the same harvesting supply here:
    // under fault injection it behaves as the unprotected runtime the
    // docs describe, and its profile (no replay, no persistent state,
    // single-cycle rounds) must still hold.
    let engine = harvesting(kind, SYN_HORIZON);
    let mut spec = RuntimeSpec::new(PERIOD);
    if matches!(policy, Policy::Smart { .. } | Policy::Adaptive { .. }) {
        spec = spec.with_smart_table(synthetic_table());
    }
    let rt = policy.runtime::<TrackedProgram<SyntheticProgram>>(&spec);
    run_checked(program, engine, rt.as_ref(), plan, &policy.profile())
}

/// Per-cell structural assertions beyond checker cleanliness: precise
/// runtimes never drop a recorded round and emit at full precision;
/// approximate runtimes bill nothing to the state ledger.
fn assert_cell_invariants(cell: &str, policy: Policy, run: &CheckedRun<usize>) {
    assert!(run.campaign.violations.is_empty(), "{cell}: driver violations");
    match policy {
        Policy::Chinchilla | Policy::Alpaca => {
            for r in &run.campaign.rounds {
                assert!(
                    r.emitted_at.is_some(),
                    "{cell}: precise runtime dropped round {}",
                    r.sample_id
                );
                assert_eq!(r.output, Some(SYN_STEPS), "{cell}: partial-precision emit");
            }
        }
        Policy::Greedy | Policy::Smart { .. } => {
            assert_eq!(
                run.campaign.state_energy, 0.0,
                "{cell}: approx runtime billed the state ledger"
            );
        }
        Policy::Adaptive { .. } => {
            // The learner persists a bounded few-words state: at most
            // one restore read plus three persists of `STATE_WORDS` per
            // round, every one billed through the state ledger.
            let mcu = McuModel::paper_default();
            let per_round = mcu.energy(&OpCost {
                fram_reads: STATE_WORDS,
                fram_writes: 3 * STATE_WORDS,
                ..Default::default()
            });
            let ceiling = per_round * run.campaign.rounds.len().max(1) as f64;
            assert!(
                run.campaign.state_energy <= ceiling + 1e-12,
                "{cell}: state energy {} above the bounded-state ceiling {}",
                run.campaign.state_energy,
                ceiling
            );
            for r in run.campaign.emitted() {
                assert_eq!(r.latency_cycles, 0, "{cell}: adaptive emit crossed a cycle");
            }
        }
        Policy::Continuous => {}
    }
}

#[test]
fn exhaustive_single_fault_enumeration_on_synthetic() {
    for kind in KINDS {
        for policy in synthetic_policies() {
            let name = format!("{}/{}", policy.name(), kind_name(kind));
            let free = checked_synthetic(policy, kind, FaultPlan::None);
            assert_no_violations(&format!("{name} fault-free"), &free.violations);
            assert_cell_invariants(&format!("{name} fault-free"), policy, &free);
            assert!(free.ops > 10, "{name}: implausibly small fault-point space");
            for t in 0..free.ops {
                let cell = format!("{name} fault@{t}");
                let run = checked_synthetic(policy, kind, FaultPlan::single(t));
                assert_no_violations(&cell, &run.violations);
                assert_cell_invariants(&cell, policy, &run);
                assert_eq!(run.injected, 1, "{cell}: the armed fault must fire");
            }
        }
    }
}

#[test]
fn randomized_schedules_keep_shipping_runtimes_clean_on_synthetic() {
    let seeds = fault_seeds(200);
    for kind in KINDS {
        for policy in synthetic_policies() {
            for seed in 0..seeds {
                let cell =
                    format!("{}/{} seed {seed}", policy.name(), kind_name(kind));
                let run = checked_synthetic(policy, kind, FaultPlan::random(seed, 0.05));
                assert_no_violations(&cell, &run.violations);
                assert_cell_invariants(&cell, policy, &run);
            }
        }
    }
}

#[test]
fn fault_schedules_are_bitwise_reproducible_by_seed() {
    let mut any_injected = false;
    for kind in KINDS {
        for policy in [Policy::Chinchilla, Policy::Greedy] {
            for seed in 0..5u64 {
                let a = checked_synthetic(policy, kind, FaultPlan::random(seed, 0.2));
                let b = checked_synthetic(policy, kind, FaultPlan::random(seed, 0.2));
                let cell = format!("{}/{} seed {seed}", policy.name(), kind_name(kind));
                assert_eq!(a.injected, b.injected, "{cell}: injected count");
                assert_eq!(a.ops, b.ops, "{cell}: op count");
                assert_eq!(a.trace.events.len(), b.trace.events.len(), "{cell}: trace");
                assert_eq!(a.trace.emits(), b.trace.emits(), "{cell}: emits");
                assert_eq!(a.campaign.rounds.len(), b.campaign.rounds.len(), "{cell}");
                for (ra, rb) in a.campaign.rounds.iter().zip(b.campaign.rounds.iter()) {
                    assert_eq!(ra.sample_id, rb.sample_id, "{cell}");
                    assert_eq!(
                        ra.acquired_at.to_bits(),
                        rb.acquired_at.to_bits(),
                        "{cell}: acquisition time not bitwise equal"
                    );
                    assert_eq!(
                        ra.emitted_at.map(f64::to_bits),
                        rb.emitted_at.map(f64::to_bits),
                        "{cell}: emission time not bitwise equal"
                    );
                    assert_eq!(ra.steps_executed, rb.steps_executed, "{cell}");
                    assert_eq!(ra.latency_cycles, rb.latency_cycles, "{cell}");
                    assert_eq!(ra.output, rb.output, "{cell}");
                }
                any_injected |= a.injected > 0;
            }
        }
    }
    assert!(any_injected, "no schedule injected anything at rate 0.2 — plan wiring broken");
}

// ---------------------------------------------------------------------
// Workload coverage: HAR, acoustic, Harris — the paper's three apps.
// ---------------------------------------------------------------------

fn har_fixture() -> &'static (AnytimeSvm, Vec<LabelledWindow>) {
    static FIXTURE: OnceLock<(AnytimeSvm, Vec<LabelledWindow>)> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let spec = CorpusSpec {
            train_volunteers: 2,
            test_volunteers: 1,
            windows_per_volunteer_per_class: 2,
        };
        let corpus = Corpus::generate(&spec, 42);
        let (rows, labels) = Corpus::features(&corpus.train);
        let svm = train_ovr(&rows, &labels, 6, &TrainConfig::default());
        let windows = corpus.test.iter().take(2).cloned().collect();
        (AnytimeSvm::by_coefficient_magnitude(svm), windows)
    })
}

fn checked_har(policy: Policy, kind: EngineKind, plan: FaultPlan) -> CheckedRun<HarOutput> {
    let (asvm, windows) = har_fixture();
    let program = HarProgram::new(asvm.clone(), WindowSource::List(windows.clone()));
    let engine = harvesting(kind, 400.0);
    let rt = policy.runtime::<TrackedProgram<HarProgram>>(&RuntimeSpec::new(PERIOD));
    run_checked(program, engine, rt.as_ref(), plan, &policy.profile())
}

fn checked_audio(policy: Policy, kind: EngineKind, plan: FaultPlan) -> CheckedRun<AudioOutput> {
    let detector = SpectralDetector::paper_default();
    let windows: Vec<_> = labelled_windows(1, 3).into_iter().take(2).collect();
    let program = AudioProgram::new(detector.clone(), AudioSource::List(windows));
    let engine = harvesting(kind, 400.0);
    let mut spec = RuntimeSpec::new(PERIOD);
    if let Policy::Smart { .. } = policy {
        spec = spec.with_smart_table(audio_app::smart_table(&detector, &McuModel::paper_default()));
    }
    let rt = policy.runtime::<TrackedProgram<AudioProgram>>(&spec);
    run_checked(program, engine, rt.as_ref(), plan, &policy.profile())
}

fn checked_harris(policy: Policy, kind: EngineKind, plan: FaultPlan) -> CheckedRun<CornerOutput> {
    // The corner program's input pool never ends, so the horizon bounds
    // the campaign: three sampling slots at t = 0, 60, 120.
    let program = CornerProgram::new(HarrisConfig::default(), 24, &[1], 7);
    let engine = harvesting(kind, 150.0);
    let rt = policy.runtime::<TrackedProgram<CornerProgram>>(&RuntimeSpec::new(PERIOD));
    run_checked(program, engine, rt.as_ref(), plan, &policy.profile())
}

fn workload_policies() -> Vec<Policy> {
    vec![Policy::Continuous, Policy::Chinchilla, Policy::Alpaca, Policy::Greedy]
}

fn precise(policy: Policy) -> bool {
    matches!(policy, Policy::Chinchilla | Policy::Alpaca)
}

/// Exhaustively enumerate every cycle boundary for one workload runner
/// and assert checker cleanliness; for the precise runtimes, emitted
/// outputs must additionally be exactly the fault-free outputs (the
/// sample streams are index-deterministic lists, so equality per
/// `sample_id` is the right notion of "the faults changed nothing").
fn enumerate_workload<O, F>(label: &str, policy: Policy, kind: EngineKind, runner: F)
where
    O: Clone + PartialEq + std::fmt::Debug,
    F: Fn(Policy, EngineKind, FaultPlan) -> CheckedRun<O>,
{
    let name = format!("{label}/{}/{}", policy.name(), kind_name(kind));
    let free = runner(policy, kind, FaultPlan::None);
    assert_no_violations(&format!("{name} fault-free"), &free.violations);
    assert!(
        free.campaign.emitted().count() > 0,
        "{name}: fault-free campaign emitted nothing — cell mis-sized"
    );
    let reference: Vec<(u64, O)> = free
        .campaign
        .emitted()
        .map(|r| (r.sample_id, r.output.clone().expect("emitted")))
        .collect();
    for t in 0..free.ops {
        let cell = format!("{name} fault@{t}");
        let run = runner(policy, kind, FaultPlan::single(t));
        assert_no_violations(&cell, &run.violations);
        assert!(run.campaign.violations.is_empty(), "{cell}: driver violations");
        if precise(policy) {
            for r in run.campaign.emitted() {
                let expected = reference
                    .iter()
                    .find(|(id, _)| *id == r.sample_id)
                    .map(|(_, o)| o);
                assert_eq!(
                    r.output.as_ref(),
                    expected,
                    "{cell}: faulted output diverged from fault-free output"
                );
            }
        } else if matches!(policy, Policy::Greedy | Policy::Smart { .. }) {
            assert_eq!(run.campaign.state_energy, 0.0, "{cell}: approx state energy");
        }
    }
}

#[test]
fn exhaustive_enumeration_covers_har_workload() {
    for kind in KINDS {
        for policy in workload_policies() {
            enumerate_workload("har", policy, kind, checked_har);
        }
    }
}

#[test]
fn exhaustive_enumeration_covers_audio_workload() {
    for kind in KINDS {
        for policy in workload_policies() {
            enumerate_workload("audio", policy, kind, checked_audio);
        }
        // SMART has an offline table for this workload — cover it too.
        enumerate_workload("audio", Policy::Smart { bound: 0.60 }, kind, checked_audio);
    }
}

#[test]
fn exhaustive_enumeration_covers_harris_workload() {
    for kind in KINDS {
        for policy in workload_policies() {
            // `CornerOutput` carries no `PartialEq`; compare the corner
            // list and perforation coverage instead.
            let name = format!("harris/{}/{}", policy.name(), kind_name(kind));
            let free = checked_harris(policy, kind, FaultPlan::None);
            assert_no_violations(&format!("{name} fault-free"), &free.violations);
            assert!(free.campaign.emitted().count() > 0, "{name}: nothing emitted");
            let reference: Vec<(u64, Vec<aic::imgproc::Corner>, usize)> = free
                .campaign
                .emitted()
                .map(|r| {
                    let o = r.output.as_ref().expect("emitted");
                    (r.sample_id, o.corners.clone(), o.rows_computed)
                })
                .collect();
            for t in 0..free.ops {
                let cell = format!("{name} fault@{t}");
                let run = checked_harris(policy, kind, FaultPlan::single(t));
                assert_no_violations(&cell, &run.violations);
                if precise(policy) {
                    for r in run.campaign.emitted() {
                        let o = r.output.as_ref().expect("emitted");
                        let expected = reference.iter().find(|(id, _, _)| *id == r.sample_id);
                        if let Some((_, corners, rows)) = expected {
                            assert_eq!(&o.corners, corners, "{cell}: corners diverged");
                            assert_eq!(o.rows_computed, *rows, "{cell}: perforation diverged");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn randomized_schedules_keep_workloads_clean() {
    let seeds = fault_seeds(200);
    for kind in KINDS {
        for policy in workload_policies() {
            for seed in 0..seeds {
                let plan = FaultPlan::Random { seed, rate: 0.02, max_faults: u64::MAX };
                let cell = format!("{}/{} seed {seed}", policy.name(), kind_name(kind));
                let har = checked_har(policy, kind, plan.clone());
                assert_no_violations(&format!("har/{cell}"), &har.violations);
                let audio = checked_audio(policy, kind, plan.clone());
                assert_no_violations(&format!("audio/{cell}"), &audio.violations);
                let harris = checked_harris(policy, kind, plan);
                assert_no_violations(&format!("harris/{cell}"), &harris.violations);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dropped-round semantics: mid-round failure vs deliberate skip.
// ---------------------------------------------------------------------

#[test]
fn dropped_after_mid_round_failure_goes_straight_to_recharging() {
    for kind in KINDS {
        // GREEDY op ordinals on the synthetic program: 0 = acquire,
        // 1.. = steps, last = emit. Fault the second step.
        let run = checked_synthetic(Policy::Greedy, kind, FaultPlan::single(2));
        assert_no_violations(&format!("greedy mid-step {}", kind_name(kind)), &run.violations);
        let rounds = &run.campaign.rounds;
        assert!(rounds.len() >= 2, "campaign too short: {} rounds", rounds.len());
        assert!(rounds[0].emitted_at.is_none(), "faulted round must not emit");
        assert_eq!(rounds[0].steps_executed, 1, "one step billed before the fault");
        // `sleep: false`: the next acquisition happens as soon as the
        // capacitor recovers, well before the next sampling slot.
        let delta = rounds[1].acquired_at - rounds[0].acquired_at;
        assert!(delta > 0.0, "time must advance over the recharge");
        assert!(
            delta < PERIOD,
            "{}: mid-round drop slept to the next slot (Δ={delta:.1}s)",
            kind_name(kind)
        );
    }
}

#[test]
fn dropped_on_deliberate_skip_sleeps_to_the_next_slot() {
    for kind in KINDS {
        // A bound above the table's best accuracy makes every round
        // infeasible: SMART skips deliberately, with `sleep: true`.
        let run = checked_synthetic(Policy::Smart { bound: 0.95 }, kind, FaultPlan::None);
        assert_no_violations(&format!("smart skip {}", kind_name(kind)), &run.violations);
        let rounds = &run.campaign.rounds;
        assert!(rounds.len() >= 2, "expected several skipped slots");
        for r in rounds {
            assert!(r.emitted_at.is_none() && r.steps_executed == 0, "skip does no work");
        }
        for (i, r) in rounds.iter().enumerate() {
            let slot = i as f64 * PERIOD;
            assert!(
                (r.acquired_at - slot).abs() < 1.5,
                "{}: skip {i} acquired at {:.2}s, not slot-aligned to {slot:.0}s",
                kind_name(kind),
                r.acquired_at
            );
        }
    }
}

#[test]
fn dropped_on_emit_failure_keeps_the_executed_steps() {
    for kind in KINDS {
        // Continuous op ordinals: 0 = acquire, 1..=8 = steps, 9 = emit.
        let run = checked_synthetic(Policy::Continuous, kind, FaultPlan::single(9));
        assert_no_violations(&format!("continuous emit {}", kind_name(kind)), &run.violations);
        let r0 = &run.campaign.rounds[0];
        assert!(r0.emitted_at.is_none(), "emission browned out");
        assert_eq!(r0.steps_executed, SYN_STEPS, "all steps ran before the lost emit");
    }
}

// ---------------------------------------------------------------------
// Alpaca re-entry: failure at every boundary restores exactly the
// committed prefix.
// ---------------------------------------------------------------------

fn alpaca_reenter_run(kind: EngineKind, plan: FaultPlan) -> CheckedRun<usize> {
    let rt = AlpacaRuntime::new(AlpacaConfig {
        steps_per_task: 4,
        sample_period: PERIOD,
        ..Default::default()
    });
    run_checked(
        SyntheticProgram::new(1, 12, SYN_CYCLES),
        harvesting(kind, SYN_HORIZON),
        &rt,
        plan,
        &alpaca::profile(),
    )
}

#[test]
fn alpaca_reenter_restores_exactly_the_committed_prefix() {
    for kind in KINDS {
        let free = alpaca_reenter_run(kind, FaultPlan::None);
        assert_no_violations(&format!("alpaca reenter {} fault-free", kind_name(kind)),
            &free.violations);
        for t in 0..free.ops {
            let cell = format!("alpaca reenter {} fault@{t}", kind_name(kind));
            let run = alpaca_reenter_run(kind, FaultPlan::single(t));
            assert_no_violations(&cell, &run.violations);
            // Every re-entry replays a whole-task prefix: 0, 4, 8 or 12
            // steps — never a partial task, never beyond the program.
            for (sample, len) in run.trace.replay_runs() {
                assert!(
                    len % 4 == 0 && len <= 12,
                    "{cell}: sample {sample} replayed {len} steps — not a committed task prefix"
                );
            }
            for r in run.campaign.emitted() {
                assert_eq!(r.output, Some(12), "{cell}: partial-precision emit");
                assert_eq!(r.steps_executed, 12, "{cell}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Mutation gate: the deliberately broken runtimes must be flagged.
// CI selects these by name: `cargo test --test fault_injection mutation_gate`.
// ---------------------------------------------------------------------

fn has_kind(run_violations: &[aic::exec::Violation], kind: &str) -> bool {
    run_violations.iter().any(|v| v.kind() == kind)
}

#[test]
fn mutation_gate_missing_war_versioning_is_flagged() {
    for kind in KINDS {
        let rt = NoWarChinchillaRuntime { sample_period: PERIOD };
        let run = run_checked(
            SyntheticProgram::new(SYN_INPUTS, SYN_STEPS, SYN_CYCLES),
            harvesting(kind, SYN_HORIZON),
            &rt,
            FaultPlan::None,
            &chinchilla::profile(),
        );
        assert!(
            has_kind(&run.violations, "unversioned-war-write"),
            "{}: WAR-stripped Chinchilla passed the checker: {:?}",
            kind_name(kind),
            run.violations
        );
        // The shipping counterpart is clean under identical conditions.
        let ok = checked_synthetic(Policy::Chinchilla, kind, FaultPlan::None);
        assert_no_violations("shipping chinchilla", &ok.violations);
    }
}

#[test]
fn mutation_gate_persistent_state_in_volatile_runtime_is_flagged() {
    for kind in KINDS {
        let rt = PersistentGreedyRuntime { sample_period: PERIOD };
        let run = run_checked(
            SyntheticProgram::new(SYN_INPUTS, SYN_STEPS, SYN_CYCLES),
            harvesting(kind, SYN_HORIZON),
            &rt,
            FaultPlan::None,
            &approx::profile(),
        );
        assert!(
            has_kind(&run.violations, "stateful-volatile-runtime"),
            "{}: checkpointing GREEDY passed the volatility check: {:?}",
            kind_name(kind),
            run.violations
        );
        assert!(run.campaign.state_energy > 0.0, "the mutant must actually persist");
        let ok = checked_synthetic(Policy::Greedy, kind, FaultPlan::None);
        assert_no_violations("shipping greedy", &ok.violations);
        assert_eq!(ok.campaign.state_energy, 0.0);
    }
}

#[test]
fn mutation_gate_commit_before_execution_is_flagged_under_faults() {
    for kind in KINDS {
        let make_run = |plan: FaultPlan| {
            let rt = EarlyCommitAlpacaRuntime { steps_per_task: 4, sample_period: PERIOD };
            run_checked(
                SyntheticProgram::new(1, SYN_STEPS, SYN_CYCLES),
                harvesting(kind, SYN_HORIZON),
                &rt,
                plan,
                &alpaca::profile(),
            )
        };
        // Fault-free the mutant is indistinguishable from the real
        // thing — the whole point of fault injection.
        let free = make_run(FaultPlan::None);
        assert_no_violations("early-commit mutant, fault-free", &free.violations);
        let mut flagged = 0usize;
        for t in 0..free.ops {
            let run = make_run(FaultPlan::single(t));
            if has_kind(&run.violations, "replay-beyond-commit") {
                flagged += 1;
            }
        }
        assert!(
            flagged > 0,
            "{}: no enumerated fault exposed the early commit",
            kind_name(kind)
        );
    }
}

#[test]
fn mutation_gate_emit_before_commit_is_flagged_under_faults() {
    for kind in KINDS {
        let make_run = |plan: FaultPlan| {
            let rt = EmitBeforeCommitRuntime { sample_period: PERIOD };
            run_checked(
                SyntheticProgram::new(1, SYN_STEPS, SYN_CYCLES),
                harvesting(kind, SYN_HORIZON),
                &rt,
                plan,
                &alpaca::profile(),
            )
        };
        let free = make_run(FaultPlan::None);
        assert_no_violations("emit-before-commit mutant, fault-free", &free.violations);
        let mut flagged = 0usize;
        for t in 0..free.ops {
            let run = make_run(FaultPlan::single(t));
            if has_kind(&run.violations, "double-emit") {
                flagged += 1;
            }
        }
        assert!(
            flagged > 0,
            "{}: no enumerated fault exposed the early emission",
            kind_name(kind)
        );
        // The shipping precise runtimes survive the same enumeration —
        // the dense version lives in the exhaustive tests above; here a
        // single adversarial ordinal (the one most likely to double-emit,
        // right after the emission) documents the contrast.
        let emit_ordinal = free.ops.saturating_sub(1);
        let ok = checked_synthetic(Policy::Alpaca, kind, FaultPlan::single(emit_ordinal));
        assert_no_violations("shipping alpaca at the emit boundary", &ok.violations);
    }
}

// ---------------------------------------------------------------------
// The fault-point space itself: enumeration must actually cover it.
// ---------------------------------------------------------------------

#[test]
fn fault_point_space_is_stable_and_every_ordinal_reachable() {
    for kind in KINDS {
        let a = checked_synthetic(Policy::Chinchilla, kind, FaultPlan::None);
        let b = checked_synthetic(Policy::Chinchilla, kind, FaultPlan::None);
        assert_eq!(a.ops, b.ops, "fault-free op count must be deterministic");
        // A fault at the very last fault-free ordinal must still fire:
        // the space reported by `ops` is fully reachable.
        let last = a.ops - 1;
        let run = checked_synthetic(Policy::Chinchilla, kind, FaultPlan::single(last));
        assert_eq!(run.injected, 1, "{}: ordinal {last} unreachable", kind_name(kind));
        // Beyond the (now longer) faulted campaign's own op count,
        // nothing fires.
        let beyond = checked_synthetic(Policy::Chinchilla, kind, FaultPlan::single(100_000));
        assert_eq!(beyond.injected, 0);
        assert_no_violations("beyond-horizon ordinal", &beyond.violations);
    }
}
