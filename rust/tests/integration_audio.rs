//! Integration gates for the third workload: the acceptance criteria of
//! the audio PR.
//!
//! * detection accuracy is monotonically non-decreasing in completed
//!   refinement steps, and a powered (continuous) run — which completes
//!   every step — is exact;
//! * the committed `examples/scenarios/audio_ambient.json` grid runs
//!   end-to-end and its rendered results are bitwise identical for any
//!   worker-pool size (the `AIC_WORKERS=1` vs `8` gate);
//! * the audio workload slots into the scenario machinery exactly like
//!   HAR and imaging: builtin registry, JSON round-trip, cells rows.

use aic::audio::app::{AudioProgram, AudioSource};
use aic::audio::detector::SpectralDetector;
use aic::audio::stream::{labelled_windows, AudioScript};
use aic::audio::NUM_PROBES;
use aic::coordinator::metrics;
use aic::coordinator::scenario::{builtin, HarvesterSpec, Scenario};
use aic::energy::mcu::McuModel;
use aic::exec::engine::Engine;
use aic::exec::{Policy, Runtime, RuntimeSpec};

#[test]
fn accuracy_is_monotone_in_refinement_steps() {
    // Over a class-balanced labelled set AND over script-sampled
    // windows: every additional probe can only add a detectable class.
    let d = SpectralDetector::paper_default();
    let ps: Vec<usize> = (0..=NUM_PROBES).collect();
    let balanced = d.accuracy_curve(&labelled_windows(6, 0xACC), &ps);
    let script = AudioScript::generate(4.0 * 3600.0, 9);
    let scripted: Vec<_> = (0..200).map(|i| script.window_at(30.0 * i as f64)).collect();
    let streamed = d.accuracy_curve(&scripted, &ps);
    for curve in [&balanced, &streamed] {
        for p in 1..curve.len() {
            assert!(
                curve[p] >= curve[p - 1],
                "accuracy dipped at step {p}: {} -> {}",
                curve[p - 1],
                curve[p]
            );
        }
        assert!((curve[NUM_PROBES] - 1.0).abs() < 1e-12, "full refinement not exact");
    }
    // The knob is real: chance at zero probes, perfect at full depth.
    assert!(balanced[0] < 0.2);
}

#[test]
fn powered_continuous_run_completes_every_step_and_is_exact() {
    let mut program = AudioProgram::new(
        SpectralDetector::paper_default(),
        AudioSource::Script(AudioScript::generate(1800.0, 4)),
    );
    let mut engine = Engine::powered(McuModel::paper_default(), 1800.0);
    let spec = RuntimeSpec::new(30.0);
    let c = Policy::Continuous.runtime::<AudioProgram>(&spec).run(&mut program, &mut engine);
    assert!(c.emitted().count() > 10, "continuous run barely emitted");
    for r in c.emitted() {
        assert_eq!(r.steps_executed, NUM_PROBES);
        let out = r.output.as_ref().unwrap();
        assert_eq!(out.probes_used, NUM_PROBES);
        assert_eq!(out.predicted, out.truth, "full refinement must be exact");
    }
    assert!((metrics::audio_accuracy(&c) - 1.0).abs() < 1e-12);
}

fn committed_audio_scenario() -> Scenario {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/scenarios/audio_ambient.json"
    );
    Scenario::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

#[test]
fn audio_ambient_example_is_the_advertised_grid() {
    let sc = committed_audio_scenario();
    assert!(
        sc.harvesters.iter().all(|h| matches!(h, HarvesterSpec::Ambient(_))),
        "the example is about ambient supplies"
    );
    assert_eq!(sc.harvesters.len(), 5, "all five ambient traces");
    assert_eq!(sc.policies.len(), 5, "all five policies");
    // Lossless round trip, like every scenario file.
    let rt = Scenario::parse(&sc.to_json_string()).unwrap();
    assert_eq!(rt.plan(), sc.plan());
}

#[test]
fn audio_ambient_sweep_is_bitwise_identical_for_any_worker_count() {
    // The acceptance gate: `aic sweep examples/scenarios/audio_ambient
    // .json` under AIC_WORKERS=1 vs 8 — here through the same code path
    // with explicit pool sizes, comparing the rendered tables (the bytes
    // every sink receives) for equality.
    let sc = committed_audio_scenario();
    let one = sc.run_with(true, None, Some(1)).tables();
    let eight = sc.run_with(true, None, Some(8)).tables();
    assert_eq!(one, eight, "sweep output depends on the pool size");
    // One row per cell of the fast-resolved plan.
    assert_eq!(one[0].rows.len(), sc.resolve(true).plan().len());
}

#[test]
fn audio_builtin_runs_and_summarises_every_policy() {
    let sc = builtin("audio", 3).expect("audio builtin");
    sc.validate().expect("audio builtin validates");
    let run = sc.run_with(true, None, Some(2));
    let tables = run.tables();
    assert_eq!(tables.len(), 1);
    assert_eq!(tables[0].rows.len(), sc.policies.len(), "one row per policy");
    let rows = run.audio_policy_rows();
    let cont = rows.iter().find(|r| r.policy == Policy::Continuous).unwrap();
    let greedy = rows.iter().find(|r| r.policy == Policy::Greedy).unwrap();
    // The continuous ceiling completes the full refinement and is exact;
    // greedy delivers in the acquisition cycle by construction.
    assert!((cont.mean_probes - NUM_PROBES as f64).abs() < 1e-9);
    assert!(cont.accuracy > 0.99);
    assert!((greedy.same_cycle_fraction - 1.0).abs() < 1e-9);
    // Nobody can refine deeper than the precise baseline.
    for r in &rows {
        assert!(r.mean_probes <= NUM_PROBES as f64 + 1e-9, "{:?}", r.policy);
    }
}
