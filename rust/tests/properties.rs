//! Cross-module property tests via the in-tree testkit.

use aic::energy::capacitor::Capacitor;
use aic::energy::estimator::EnergyProfile;
use aic::energy::mcu::{McuModel, OpCost};
use aic::imgproc::equivalence::equivalent;
use aic::imgproc::Corner;
use aic::svm::analysis::{coherence_binary, coherence_binary_symmetric};
use aic::util::fixed::{dot_q15, Q15};
use aic::util::testkit::{property, Gen};

#[test]
fn capacitor_charge_discharge_roundtrip() {
    property("capacitor roundtrip", 256, |g: &mut Gen| {
        let mut cap = Capacitor::paper_default();
        cap.set_voltage(g.f64_in(2.0..3.5));
        let e0 = cap.energy();
        let de = g.f64_in(0.0..1e-3);
        cap.charge(de);
        let gained = cap.energy() - e0;
        assert!(gained <= de + 1e-15, "charged more than deposited");
        if cap.voltage() < cap.v_max - 1e-9 {
            assert!((gained - de).abs() < 1e-12, "lost energy without hitting rail");
        }
        let ok = cap.discharge(gained.min(cap.energy() * 0.5));
        assert!(ok);
    });
}

#[test]
fn usable_energy_never_exceeds_total() {
    property("usable <= total", 256, |g: &mut Gen| {
        let mut cap = Capacitor::paper_default();
        cap.set_voltage(g.f64_in(0.0..3.6));
        assert!(cap.usable_energy() <= cap.energy() + 1e-15);
        assert!(cap.usable_energy() >= 0.0);
    });
}

#[test]
fn mcu_energy_is_additive_and_monotone() {
    property("mcu additivity", 256, |g: &mut Gen| {
        let m = McuModel::paper_default();
        let a = OpCost {
            cycles: g.usize_in(0..=1_000_000) as u64,
            fram_reads: g.usize_in(0..=1000) as u64,
            fram_writes: g.usize_in(0..=1000) as u64,
            ..Default::default()
        };
        let b = OpCost::cycles(g.usize_in(0..=1_000_000) as u64);
        let sum = m.energy(&a.plus(&b));
        assert!((sum - m.energy(&a) - m.energy(&b)).abs() < 1e-15);
        let bigger = OpCost { cycles: a.cycles + 1, ..a };
        assert!(m.energy(&bigger) > m.energy(&a));
    });
}

#[test]
fn energy_profile_prefix_sums_consistent() {
    property("profile prefix sums", 128, |g: &mut Gen| {
        let m = McuModel::paper_default();
        let n = g.usize_in(1..=50);
        let costs: Vec<OpCost> =
            (0..n).map(|_| OpCost::cycles(1 + g.usize_in(0..=500_000) as u64)).collect();
        let p = EnergyProfile::from_costs(&m, &costs);
        // span(0, n) == total; max_steps_within(total) == n.
        assert!((p.span(0, n) - p.total()).abs() < 1e-15);
        assert_eq!(p.max_steps_within(p.total() + 1e-12, 0.0), n);
        // Any budget returns a k whose cumulative fits.
        let budget = g.f64_in(0.0..p.total() * 1.2);
        let k = p.max_steps_within(budget, 0.0);
        assert!(p.cumulative[k] <= budget + 1e-15);
        if k < n {
            assert!(p.cumulative[k + 1] > budget - 1e-12);
        }
    });
}

#[test]
fn q15_dot_product_tracks_float() {
    property("q15 dot", 128, |g: &mut Gen| {
        let n = g.usize_in(1..=140);
        let a: Vec<f64> = (0..n).map(|_| g.f64_in(-0.05..0.05)).collect();
        let b: Vec<f64> = (0..n).map(|_| g.f64_in(-0.05..0.05)).collect();
        let qa: Vec<Q15> = a.iter().map(|&x| Q15::from_f64(x)).collect();
        let qb: Vec<Q15> = b.iter().map(|&x| Q15::from_f64(x)).collect();
        let exact: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got = dot_q15(&qa, &qb).to_f64();
        assert!((got - exact).abs() < 4e-3, "got={got} exact={exact} n={n}");
    });
}

#[test]
fn coherence_formulas_agree_in_zero_mean_case() {
    property("Eq.7 consistency", 64, |g: &mut Gen| {
        let var_s = g.f64_in(0.01..5.0);
        let var_r = g.f64_in(0.01..5.0);
        let a = coherence_binary_symmetric(var_s, var_r);
        let b = coherence_binary(0.0, var_s, 0.0, var_r);
        assert!((a - b).abs() < 1e-6, "symmetric {a} vs general {b}");
        // Bounds: coherence in [0.5, 1] for zero-mean.
        assert!((0.5..=1.0 + 1e-9).contains(&a), "a={a}");
    });
}

#[test]
fn coherence_monotone_in_processed_variance() {
    property("Eq.7 monotonicity", 64, |g: &mut Gen| {
        let total = g.f64_in(0.5..4.0);
        let f1 = g.f64_in(0.05..0.45);
        let f2 = f1 + 0.3;
        let lo = coherence_binary_symmetric(total * f1, total * (1.0 - f1));
        let hi = coherence_binary_symmetric(total * f2, total * (1.0 - f2));
        assert!(hi >= lo - 1e-9, "processing more must not reduce coherence");
    });
}

#[test]
fn equivalence_is_reflexive_and_shift_tolerant() {
    property("equivalence reflexive", 128, |g: &mut Gen| {
        let n = g.usize_in(0..=12);
        let mut corners = Vec::new();
        for _ in 0..n {
            corners.push(Corner {
                x: g.usize_in(0..=100) * 13 % 150,
                y: g.usize_in(0..=100) * 7 % 150,
                response: 1.0,
            });
        }
        corners.dedup_by(|a, b| a.x == b.x && a.y == b.y);
        assert!(equivalent(&corners, &corners));
        // Dropping one corner breaks equivalence.
        if corners.len() > 1 {
            assert!(!equivalent(&corners, &corners[1..]));
        }
    });
}

#[test]
fn trace_generation_energy_scales_with_duration() {
    property("trace energy scaling", 16, |g: &mut Gen| {
        use aic::energy::traces::{generate, TraceKind};
        let kind = *g.pick(&TraceKind::ALL);
        let seed = g.usize_in(0..=1000) as u64;
        let short = generate(kind, 120.0, 0.01, seed);
        let long = generate(kind, 480.0, 0.01, seed);
        let ratio = long.total_energy() / short.total_energy().max(1e-12);
        assert!(
            (1.5..12.0).contains(&ratio),
            "{kind:?}: 4x duration gave {ratio}x energy"
        );
    });
}
