//! End-to-end imaging integration: perforated Harris campaigns across
//! energy traces, equivalence accounting, and the §6.3 relations.

use aic::coordinator::experiment::{run_img_policy, ImgRunSpec};
use aic::coordinator::scenario::perforation_rows;
use aic::coordinator::metrics::{
    corner_equivalence_fraction, same_cycle_fraction, throughput_ratio,
};
use aic::energy::traces::TraceKind;
use aic::exec::Policy;
use aic::imgproc::equivalence::equivalent;
use aic::imgproc::harris::{harris_full, harris_perforated, HarrisConfig};
use aic::imgproc::images::{render, Picture};

#[test]
fn zero_perforation_is_exactly_the_reference() {
    for picture in Picture::ALL {
        let img = render(picture, 96, 96, 17);
        let cfg = HarrisConfig::default();
        let full = harris_full(&img, &cfg);
        let p0 = harris_perforated(&img, &cfg, 96);
        assert_eq!(full.len(), p0.len(), "{picture:?}");
        assert!(equivalent(&full, &p0), "{picture:?}");
    }
}

#[test]
fn fig12_simple_survives_heavier_perforation_than_complex() {
    let rows = perforation_rows(128, &[0.0, 0.25, 0.42, 0.55, 0.7]);
    let max_ok = |p: Picture| -> f64 {
        rows.iter()
            .filter(|r| r.picture == p && r.equivalent)
            .map(|r| r.skip_fraction)
            .fold(0.0, f64::max)
    };
    assert!(max_ok(Picture::Checker) >= 0.42, "checker should survive 42%");
    assert!(max_ok(Picture::Checker) >= max_ok(Picture::Cluttered));
}

#[test]
fn greedy_imaging_emits_same_cycle_on_every_trace() {
    let spec = ImgRunSpec { horizon: 900.0, ..Default::default() };
    for trace in TraceKind::ALL {
        let c = run_img_policy(&spec, trace, Policy::Greedy);
        if c.emitted().count() > 0 {
            assert!(
                (same_cycle_fraction(&c) - 1.0).abs() < 1e-9,
                "{trace:?} emitted across cycles"
            );
        }
        assert_eq!(c.state_energy, 0.0);
    }
}

#[test]
fn equivalence_high_on_rich_trace() {
    let spec = ImgRunSpec { horizon: 1200.0, ..Default::default() };
    let c = run_img_policy(&spec, TraceKind::Som, Policy::Greedy);
    assert!(c.emitted().count() >= 5, "SOM should sustain many rounds");
    let eq = corner_equivalence_fraction(&c, aic::imgproc::images::EVAL_SIZE);
    assert!(eq >= 0.6, "equivalence {eq} too low on the richest trace");
}

#[test]
fn aic_beats_chinchilla_on_weak_trace() {
    let spec = ImgRunSpec { horizon: 1800.0, ..Default::default() };
    let aic_run = run_img_policy(&spec, TraceKind::Sim, Policy::Greedy);
    let chin = run_img_policy(&spec, TraceKind::Sim, Policy::Chinchilla);
    let ratio = throughput_ratio(&aic_run, &chin);
    assert!(
        ratio > 1.0 || chin.emitted().count() == 0,
        "AIC/Chinchilla ratio {ratio} on SIM"
    );
}

#[test]
fn chinchilla_imaging_is_precise() {
    let spec = ImgRunSpec { horizon: 1800.0, ..Default::default() };
    let c = run_img_policy(&spec, TraceKind::Sor, Policy::Chinchilla);
    for r in c.emitted() {
        let out = r.output.as_ref().unwrap();
        assert_eq!(out.rows_computed, out.total_rows, "chinchilla must not perforate");
    }
}

#[test]
fn imaging_campaigns_are_deterministic() {
    let spec = ImgRunSpec { horizon: 600.0, ..Default::default() };
    let a = run_img_policy(&spec, TraceKind::Rf, Policy::Greedy);
    let b = run_img_policy(&spec, TraceKind::Rf, Policy::Greedy);
    assert_eq!(a.rounds.len(), b.rounds.len());
    assert_eq!(a.power_cycles, b.power_cycles);
}
