//! Vectorised-kernel equivalence suite.
//!
//! The chunked Goertzel recurrence and the sliced Harris kernels are
//! rewrites of straightforward scalar loops; the scalar originals are
//! retained in the crate precisely so this suite can hold the rewrites
//! to them:
//!
//! * `gradients` must be **bitwise identical** to `gradients_scalar`
//!   (the sliced loop keeps the per-pixel operand order).
//! * `goertzel_power` and `response_row_with` regroup summation order,
//!   so they are held to tight relative bounds instead:
//!   `|v − s| ≤ tol · max(1, |s|)` with tol 1e-10 (short Goertzel
//!   windows) / 1e-9 (longer windows and the Harris response — the
//!   9-term tensor sums are re-bracketed column-first over values
//!   bounded by the 3×3 Sobel tensor scale).

use aic::imgproc::harris::{
    gradients, gradients_scalar, response_row, response_row_scalar, response_row_with,
    HarrisConfig, ResponseMap, RowScratch,
};
use aic::imgproc::images::{render, Picture};
use aic::imgproc::Image;
use aic::util::dsp::{goertzel_power, goertzel_power_scalar};
use aic::util::rng::Rng;

fn noise_image(w: usize, h: usize, seed: u64) -> Image {
    let mut rng = Rng::new(seed);
    let mut img = Image::new(w, h);
    for v in img.data.iter_mut() {
        *v = rng.range(0.0, 1.0);
    }
    img
}

#[test]
fn goertzel_matches_scalar_on_random_windows() {
    let mut rng = Rng::new(0x60E7);
    for trial in 0..40 {
        let n = 1 + rng.index(256);
        let x: Vec<f64> = (0..n).map(|_| rng.range(-1.5, 1.5)).collect();
        for k in [0, n / 4, n / 2, n.saturating_sub(1)] {
            let s = goertzel_power_scalar(&x, k);
            let v = goertzel_power(&x, k);
            // Windows up to 256 samples accumulate more reassociation
            // rounding than the short in-module cases; 1e-9 relative
            // still sits ~3 decades above the observed drift.
            let bound = 1e-9 * s.abs().max(1.0);
            assert!(
                (v - s).abs() <= bound,
                "trial {trial}: n={n} k={k}: chunked {v} vs scalar {s}"
            );
        }
    }
}

#[test]
fn goertzel_matches_scalar_on_every_remainder_length() {
    // Lengths 1..=9 cover every chunks_exact(4) remainder shape twice.
    let mut rng = Rng::new(0x60E8);
    for n in 1..=9usize {
        let x: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        for k in 0..n {
            let s = goertzel_power_scalar(&x, k);
            let v = goertzel_power(&x, k);
            assert!(
                (v - s).abs() <= 1e-10 * s.abs().max(1.0),
                "n={n} k={k}: chunked {v} vs scalar {s}"
            );
        }
    }
}

#[test]
fn gradients_bitwise_identical_to_scalar() {
    let mut images = vec![noise_image(33, 17, 9), noise_image(1, 5, 3), noise_image(7, 1, 4)];
    for kind in Picture::ALL {
        images.push(render(kind, 64, 64, 5));
    }
    for img in &images {
        let (vx, vy) = gradients(img);
        let (sx, sy) = gradients_scalar(img);
        // Exact equality: the sliced kernel preserves operand order.
        assert_eq!(vx, sx, "Ix differs on {}x{}", img.width, img.height);
        assert_eq!(vy, sy, "Iy differs on {}x{}", img.width, img.height);
    }
}

#[test]
fn response_rows_match_scalar_within_bound() {
    let cfg = HarrisConfig::default();
    let mut images = vec![noise_image(48, 31, 21), noise_image(2, 2, 8), noise_image(1, 6, 2)];
    for kind in Picture::ALL {
        images.push(render(kind, 80, 80, 7));
    }
    for img in &images {
        let (ix, iy) = gradients_scalar(img);
        let mut vec_map = ResponseMap::new(img.width, img.height);
        let mut ref_map = ResponseMap::new(img.width, img.height);
        let mut scratch = RowScratch::default();
        for y in 0..img.height {
            response_row_with(&ix, &iy, &mut vec_map, y, &cfg, &mut scratch);
            response_row_scalar(&ix, &iy, &mut ref_map, y, &cfg);
        }
        assert_eq!(vec_map.row_done, ref_map.row_done);
        for (i, (&v, &s)) in vec_map.r.iter().zip(&ref_map.r).enumerate() {
            let bound = 1e-9 * s.abs().max(1.0);
            assert!(
                (v - s).abs() <= bound,
                "{}x{} pixel {i}: separable {v} vs scalar {s}",
                img.width,
                img.height
            );
        }
    }
}

#[test]
fn response_row_wrapper_equals_scratch_variant() {
    let img = render(Picture::Cluttered, 40, 40, 3);
    let cfg = HarrisConfig::default();
    let (ix, iy) = gradients(&img);
    let mut a = ResponseMap::new(40, 40);
    let mut b = ResponseMap::new(40, 40);
    let mut scratch = RowScratch::default();
    for y in 0..40 {
        response_row(&ix, &iy, &mut a, y, &cfg);
        response_row_with(&ix, &iy, &mut b, y, &cfg, &mut scratch);
    }
    assert_eq!(a.r, b.r);
}
