//! Inspect a trained anytime SVM: feature ordering, per-feature
//! discriminative power, and the accuracy curve — the offline analysis a
//! deployment would run before provisioning SMART tables.
//!
//! Run: `cargo run --release --example inspect_model [--seed N] [--top K]`

use aic::coordinator::experiment::HarContext;
use aic::har::dataset::Corpus;
use aic::har::features::feature_name;
use aic::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 42);
    let top = args.get_usize("top", 25);

    eprintln!("building context (corpus + training)...");
    let ctx = HarContext::build(seed);
    let asvm = &ctx.asvm;
    println!("full-model held-out accuracy: {:.1}%", 100.0 * ctx.full_accuracy);

    // Per-feature aggregate weight magnitude and between-class spread.
    let (rows, labels) = Corpus::features(&ctx.corpus.train);
    let scaled: Vec<Vec<f64>> = rows.iter().map(|r| asvm.svm.scaler.apply(r)).collect();
    println!("\n# anytime order (top {top})");
    println!("{:<4} {:<18} {:>8} {:>10}", "rank", "feature", "sum|w|", "spread");
    for (rank, &j) in asvm.order.iter().take(top).enumerate() {
        let mag: f64 = asvm.svm.weights.iter().map(|w| w[j].abs()).sum();
        // Between-class spread of the standardised feature.
        let mut class_mean = vec![0.0; 6];
        let mut count = vec![0usize; 6];
        for (r, &l) in scaled.iter().zip(labels.iter()) {
            class_mean[l] += r[j];
            count[l] += 1;
        }
        for c in 0..6 {
            class_mean[c] /= count[c].max(1) as f64;
        }
        let spread = class_mean.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - class_mean.iter().cloned().fold(f64::INFINITY, f64::min);
        println!("{:<4} {:<18} {:>8.3} {:>10.3}", rank, feature_name(j), mag, spread);
    }

    // Accuracy curve at a few prefix lengths.
    let (test_rows, test_labels) = Corpus::features(&ctx.corpus.test);
    let ps: Vec<usize> = vec![0, 1, 2, 3, 5, 8, 12, 20, 30, 50, 80, 140];
    let acc = asvm.accuracy_curve(&test_rows, &test_labels, &ps);
    println!("\n# accuracy by prefix length");
    for (p, a) in ps.iter().zip(acc.iter()) {
        println!("p={:<4} accuracy={:.1}%", p, 100.0 * a);
    }

    // Bias magnitudes (an argmax stuck on biases shows up here).
    println!("\n# biases: {:?}", asvm.svm.bias);
}
