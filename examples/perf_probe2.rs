//! §Perf micro-probe: cost split of one GREEDY round.
use aic::energy::harvester::Harvester;
use aic::energy::traces::{generate, TraceKind};
use aic::exec::engine::{Engine, EngineConfig};
use aic::har::dataset::{ActivityScript};
use aic::har::features::extract_all;
use std::time::Instant;

fn main() {
    let trace = generate(TraceKind::Sim, 1800.0, 0.01, 1);
    let mut e = Engine::new(EngineConfig::paper_default(1e9), Harvester::Replay(trace));
    let t = Instant::now();
    for _ in 0..100 { let _ = e.sleep(57.0); e.cap.set_voltage(3.2); }
    println!("sleep(57s): {:.0} us/round", t.elapsed().as_micros() as f64 / 100.0);

    let script = ActivityScript::generate(3600.0, 1);
    let t = Instant::now();
    for i in 0..100 { let _ = script.window_at(i as f64 * 36.0); }
    println!("window_at: {:.0} us/round", t.elapsed().as_micros() as f64 / 100.0);

    let lw = script.window_at(100.0);
    let t = Instant::now();
    for _ in 0..100 { let _ = extract_all(&lw.window); }
    println!("extract_all: {:.0} us/round", t.elapsed().as_micros() as f64 / 100.0);
}
