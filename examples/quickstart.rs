//! Quickstart: the whole system in ~60 lines.
//!
//! Trains an anytime SVM on the synthetic HAR corpus, runs a GREEDY
//! approximate-intermittent device against a Chinchilla baseline on the
//! same kinetic energy, and prints the paper's headline comparison.
//!
//! Run: `cargo run --release --example quickstart`

use aic::coordinator::experiment::{run_har_policy, HarContext, HarRunSpec};
use aic::coordinator::metrics::{har_accuracy, same_cycle_fraction, throughput_ratio};
use aic::exec::Policy;

fn main() {
    // 1. Offline phase: corpus -> training -> Eq. 7 tables (all seeded).
    println!("training anytime SVM on the synthetic HAR corpus...");
    let ctx = HarContext::build(42);
    println!("  best attainable accuracy (all 140 features): {:.1}%", 100.0 * ctx.full_accuracy);

    // 2. One hour on a volunteer's wrist, three runtimes, same motion.
    let spec = HarRunSpec { horizon: 3600.0, sample_period: 60.0, script_seed: 7 };
    println!("simulating 1 h campaigns on kinetic energy...");
    let greedy = run_har_policy(&ctx, &spec, Policy::Greedy);
    let chinchilla = run_har_policy(&ctx, &spec, Policy::Chinchilla);
    let continuous = run_har_policy(&ctx, &spec, Policy::Continuous);

    // 3. The paper's headline metrics.
    println!("\n                      greedy   chinchilla   continuous");
    println!(
        "results delivered     {:>6}   {:>10}   {:>10}",
        greedy.emitted().count(),
        chinchilla.emitted().count(),
        continuous.emitted().count()
    );
    println!(
        "accuracy              {:>5.1}%   {:>9.1}%   {:>9.1}%",
        100.0 * har_accuracy(&greedy),
        100.0 * har_accuracy(&chinchilla),
        100.0 * har_accuracy(&continuous)
    );
    println!(
        "same-cycle emission   {:>5.1}%   {:>9.1}%   {:>10}",
        100.0 * same_cycle_fraction(&greedy),
        100.0 * same_cycle_fraction(&chinchilla),
        "n/a"
    );
    println!(
        "state-mgmt energy     {:>5.2}mJ  {:>8.2}mJ   {:>8.2}mJ",
        1e3 * greedy.state_energy,
        1e3 * chinchilla.state_energy,
        1e3 * continuous.state_energy
    );
    println!(
        "\nthroughput gain over Chinchilla: {:.1}x",
        throughput_ratio(&greedy, &chinchilla)
    );
    println!(
        "approximate intermittent computing emitted every result before \
         the first power failure: {}",
        same_cycle_fraction(&greedy) == 1.0
    );
}
