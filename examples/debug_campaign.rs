//! Diagnostic: per-round details of a GREEDY HAR campaign.
//! Run: cargo run --release --example debug_campaign

use aic::coordinator::experiment::{run_har_policy, HarContext, HarRunSpec};
use aic::exec::Policy;
use aic::har::Activity;

fn main() {
    let ctx = HarContext::build(42 ^ 0xC0FFEE);
    println!("ceiling accuracy = {:.1}%", 100.0 * ctx.full_accuracy);
    let spec = HarRunSpec { horizon: 7200.0, sample_period: 60.0, script_seed: 42 };
    let c = run_har_policy(&ctx, &spec, Policy::Greedy);
    let mut by_class = vec![(0usize, 0usize); 6]; // (correct, total)
    let mut feats = Vec::new();
    for r in c.emitted() {
        if let Some(o) = &r.output {
            by_class[o.truth as usize].1 += 1;
            if o.predicted == o.truth as usize {
                by_class[o.truth as usize].0 += 1;
            }
            feats.push(o.features_used as f64);
            if feats.len() <= 25 {
                println!(
                    "t={:7.0} truth={:<18} pred={:<2} p={}",
                    r.acquired_at,
                    o.truth.name(),
                    o.predicted,
                    o.features_used
                );
            }
        }
    }
    println!("\nmean features used = {:.1}", aic::util::stats::mean(&feats));
    for a in Activity::ALL {
        let (c_, t_) = by_class[a as usize];
        println!("{:<20} {}/{}", a.name(), c_, t_);
    }
}
