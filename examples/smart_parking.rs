//! Smart parking (§6's motivating scenario): embedded image processing
//! on harvested energy.
//!
//! A batteryless camera node watches a parking spot; corner information
//! decides occupancy against reference pictures. The node runs perforated
//! Harris detection under the GREEDY approximate-intermittent runtime on
//! each of the five paper traces and reports equivalence + throughput
//! against continuous and Chinchilla executions.
//!
//! Run: `cargo run --release --example smart_parking -- [--minutes 30]`

use aic::coordinator::experiment::{run_img_policy, ImgRunSpec};
use aic::coordinator::metrics::{
    corner_equivalence_fraction, same_cycle_fraction, throughput_ratio,
};
use aic::coordinator::report::{f2, pct, Table};
use aic::energy::traces::TraceKind;
use aic::exec::Policy;
use aic::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let minutes = args.get_f64("minutes", 30.0);
    let out = args.get_or("out", "out");
    let spec = ImgRunSpec { horizon: minutes * 60.0, ..Default::default() };

    let mut table = Table::new(
        "Smart parking: perforated corner detection per energy trace",
        &[
            "trace",
            "AIC results",
            "equivalent output",
            "AIC thrpt vs cont",
            "AIC/Chinchilla",
            "same-cycle",
            "mean rows computed",
        ],
    );
    for trace in TraceKind::ALL {
        println!("running {} ({} min)...", trace.name(), minutes);
        let cont = run_img_policy(&spec, trace, Policy::Continuous);
        let aic_run = run_img_policy(&spec, trace, Policy::Greedy);
        let chin = run_img_policy(&spec, trace, Policy::Chinchilla);
        let mean_rows = {
            let v: Vec<f64> = aic_run
                .emitted()
                .filter_map(|r| r.output.as_ref().map(|o| o.rows_computed as f64))
                .collect();
            aic::util::stats::mean(&v)
        };
        table.push(vec![
            trace.name().to_string(),
            aic_run.emitted().count().to_string(),
            pct(corner_equivalence_fraction(&aic_run, aic::imgproc::images::EVAL_SIZE)),
            pct(throughput_ratio(&aic_run, &cont)),
            f2(throughput_ratio(&aic_run, &chin)),
            pct(same_cycle_fraction(&aic_run)),
            f2(mean_rows),
        ]);
    }
    table.emit(out, "smart_parking").expect("write report");
    println!("occupancy updates always reach the display within the power cycle they were captured in.");
}
