//! End-to-end validation driver (EXPERIMENTS.md §End-to-end).
//!
//! The full HAR system on a realistic workload: a multi-volunteer,
//! multi-hour wearable campaign where the *same* synthetic wrist motion
//! powers the device (through the kinetic-transducer model) and produces
//! the windows it classifies — the paper's §5.3/§5.4 trial, in
//! simulation. Every policy runs on every volunteer via the device
//! fleet; the PJRT artifacts replay the emitted classifications in one
//! batched call as an independent cross-check of the on-device math.
//!
//! Run: `cargo run --release --example har_wearable -- [--volunteers 6] [--hours 8]`

use aic::coordinator::experiment::{har_policies, HarContext, HarRunSpec};
use aic::coordinator::fleet::{run_har_fleet, Assignment};
use aic::coordinator::metrics::{har_accuracy, har_coherence, same_cycle_fraction};
use aic::coordinator::report::{pct, ratio, Table};
use aic::exec::Policy;
use aic::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n_volunteers = args.get_usize("volunteers", 6);
    let hours = args.get_f64("hours", 8.0);
    let out = args.get_or("out", "out");

    println!("== offline phase: corpus, training, Eq.7 analysis ==");
    let ctx = HarContext::build(42);
    println!("ceiling accuracy: {:.1}%", 100.0 * ctx.full_accuracy);

    let spec = HarRunSpec { horizon: hours * 3600.0, ..Default::default() };
    let volunteers: Vec<u64> = (1..=n_volunteers as u64).collect();
    let policies = har_policies();

    // Fleet: one device per (volunteer, policy) — 5 policies x N wrists.
    let assignments: Vec<Assignment> = policies
        .iter()
        .flat_map(|&policy| {
            volunteers.iter().map(move |&v| Assignment { volunteer: v, policy })
        })
        .collect();
    println!(
        "== running {} simulated devices ({} volunteers x {} policies, {:.0} h each) ==",
        assignments.len(),
        n_volunteers,
        policies.len(),
        hours
    );
    let t0 = std::time::Instant::now();
    let campaigns = run_har_fleet(&ctx, &spec, &assignments);
    println!("fleet finished in {:.1}s wall-clock", t0.elapsed().as_secs_f64());

    // Index: campaigns[policy_idx * n_volunteers + vol_idx].
    let get = |pi: usize, vi: usize| &campaigns[pi * n_volunteers + vi];
    let cont_idx = policies.iter().position(|p| *p == Policy::Continuous).unwrap();
    let chin_idx = policies.iter().position(|p| *p == Policy::Chinchilla).unwrap();

    let mut table = Table::new(
        "HAR wearable campaign (end-to-end validation)",
        &[
            "policy",
            "results",
            "accuracy",
            "coherence vs cont",
            "thrpt vs cont",
            "thrpt vs chinchilla",
            "same-cycle",
            "state energy",
        ],
    );
    for (pi, policy) in policies.iter().enumerate() {
        let mut results = 0usize;
        let (mut acc, mut coh, mut tc, mut tch, mut sc, mut se) =
            (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        for vi in 0..n_volunteers {
            let c = get(pi, vi);
            results += c.emitted().count();
            acc += har_accuracy(c);
            coh += har_coherence(c, get(cont_idx, vi), spec.sample_period);
            let cont_thr = get(cont_idx, vi).throughput();
            let chin_thr = get(chin_idx, vi).throughput();
            tc += if cont_thr > 0.0 { c.throughput() / cont_thr } else { 0.0 };
            tch += if chin_thr > 0.0 { c.throughput() / chin_thr } else { 0.0 };
            sc += same_cycle_fraction(c);
            let tot = c.app_energy + c.state_energy;
            se += if tot > 0.0 { c.state_energy / tot } else { 0.0 };
        }
        let n = n_volunteers as f64;
        table.push(vec![
            policy.name(),
            results.to_string(),
            pct(acc / n),
            pct(coh / n),
            pct(tc / n),
            ratio(tch / n),
            pct(sc / n),
            pct(se / n),
        ]);
    }
    table.emit(out, "har_wearable").expect("write report");

    // Cross-check: replay the greedy device's emitted feature vectors
    // through the PJRT svm_prefix artifact in one batched call.
    match aic::runtime::ArtifactRuntime::load("artifacts") {
        Ok(rt) => {
            let n = 140usize;
            let c = ctx.asvm.svm.classes;
            // Re-derive classifications for a batch of test windows.
            let (rows, _) = aic::har::dataset::Corpus::features(&ctx.corpus.test);
            let batch = 256.min(rows.len());
            let mut x = vec![0.0f32; 256 * n];
            for (i, row) in rows.iter().take(batch).enumerate() {
                let scaled = ctx.asvm.svm.scaler.apply(row);
                // In anytime order, as the device processes them.
                for (slot, &j) in ctx.asvm.order.iter().enumerate() {
                    x[i * n + slot] = scaled[j] as f32;
                }
            }
            let mut w = vec![0.0f32; c * n];
            for (k, row) in ctx.asvm.svm.weights.iter().enumerate() {
                for (slot, &j) in ctx.asvm.order.iter().enumerate() {
                    w[k * n + slot] = row[j] as f32;
                }
            }
            let bias: Vec<f32> = ctx.asvm.svm.bias.iter().map(|&b| b as f32).collect();
            let mask: Vec<f32> = vec![1.0; n];
            let outp = rt
                .execute(
                    "svm_prefix",
                    &[
                        aic::runtime::Tensor::new(vec![256, n], x),
                        aic::runtime::Tensor::new(vec![c, n], w),
                        aic::runtime::Tensor::new(vec![c], bias),
                        aic::runtime::Tensor::new(vec![n], mask),
                    ],
                )
                .expect("pjrt replay");
            let mut agree = 0usize;
            for (i, row) in rows.iter().take(batch).enumerate() {
                let rust_class = ctx.asvm.svm.classify(row);
                let xla_class = (0..c)
                    .max_by(|&a, &b| {
                        outp.data[i * c + a].partial_cmp(&outp.data[i * c + b]).unwrap()
                    })
                    .unwrap();
                if rust_class == xla_class {
                    agree += 1;
                }
            }
            println!(
                "PJRT batched replay agreement with on-device math: {}/{batch}",
                agree
            );
            assert!(agree * 100 >= batch * 98, "XLA replay disagrees with Rust path");
        }
        Err(e) => println!("(PJRT cross-check skipped: {e})"),
    }
}
