//! §Perf probe: where does one figure campaign spend its time?
use aic::coordinator::experiment::{run_har_policy, HarContext, HarRunSpec};
use aic::exec::Policy;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let ctx = HarContext::build(42);
    println!("context build: {:.0} ms", t0.elapsed().as_millis());
    for policy in [Policy::Continuous, Policy::Chinchilla, Policy::Greedy, Policy::Smart{bound:0.8}] {
        let t = Instant::now();
        let spec = HarRunSpec { horizon: 4.0*3600.0, sample_period: 60.0, script_seed: 1 };
        let c = run_har_policy(&ctx, &spec, policy);
        println!("{:<12} {:>6.0} ms  rounds={} cycles={}", policy.name(), t.elapsed().as_millis(), c.rounds.len(), c.power_cycles);
    }
}
