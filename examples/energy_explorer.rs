//! Energy design-space explorer: the §4.1 capacitor-sizing study plus
//! runtime ablations.
//!
//! Sweeps the energy-buffer size ("a too large capacitor may take long to
//! charge ... a too small capacitor may not suffice for worst-case
//! processing"), the GREEDY safety margin, and the anytime feature order
//! (magnitude vs reversed — the §5.1 validation of Eq. 6's ordering).
//!
//! Run: `cargo run --release --example energy_explorer`

use aic::coordinator::experiment::HarContext;
use aic::coordinator::metrics::har_accuracy;
use aic::coordinator::report::{f2, pct, Table};
use aic::energy::harvester::{kinetic_power_trace, Harvester, KineticConfig};
use aic::exec::approx::{run as run_approx, ApproxConfig};
use aic::exec::engine::{Engine, EngineConfig};
use aic::har::app::{HarProgram, WindowSource};
use aic::har::dataset::ActivityScript;
use aic::svm::anytime::AnytimeSvm;
use aic::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "out");
    let horizon = args.get_f64("hours", 2.0) * 3600.0;
    let ctx = HarContext::build(42);
    let script = ActivityScript::generate(horizon, 3);
    let accel = script.accel_magnitude(50.0);
    let trace = kinetic_power_trace(&accel, 50.0, &KineticConfig::default());

    // --- Capacitor sweep (the paper's 1470 uF sizing study) ---
    let mut cap_table = Table::new(
        "Capacitor sizing sweep (GREEDY, kinetic energy)",
        &["capacitance (uF)", "results", "accuracy", "mean features", "power cycles"],
    );
    for cap_uf in [220.0, 470.0, 1000.0, 1470.0, 2200.0, 4700.0] {
        let mut cfg = EngineConfig::paper_default(horizon);
        cfg.capacitor =
            aic::energy::capacitor::Capacitor::new(cap_uf * 1e-6, 3.6, 3.0, 1.8);
        cfg.initial_voltage = 3.0;
        let mut engine = Engine::new(cfg, Harvester::Replay(trace.clone()));
        let mut prog =
            HarProgram::new(ctx.asvm.clone(), WindowSource::Script(script.clone()));
        let c = run_approx(&mut prog, &mut engine, &ApproxConfig::greedy(60.0));
        let mean_feats = {
            let v: Vec<f64> = c.emitted().map(|r| r.steps_executed as f64).collect();
            aic::util::stats::mean(&v)
        };
        cap_table.push(vec![
            format!("{cap_uf:.0}"),
            c.emitted().count().to_string(),
            pct(har_accuracy(&c)),
            f2(mean_feats),
            c.power_cycles.to_string(),
        ]);
    }
    cap_table.emit(out, "ablation_capacitor").expect("write");

    // --- GREEDY margin sweep ---
    let mut margin_table = Table::new(
        "GREEDY safety-margin sweep",
        &["margin", "results", "lost samples", "accuracy"],
    );
    for margin in [1.0, 1.05, 1.2, 1.5, 2.0] {
        let mut cfg = ApproxConfig::greedy(60.0);
        cfg.margin = margin;
        let mut engine =
            Engine::new(EngineConfig::paper_default(horizon), Harvester::Replay(trace.clone()));
        let mut prog =
            HarProgram::new(ctx.asvm.clone(), WindowSource::Script(script.clone()));
        let c = run_approx(&mut prog, &mut engine, &cfg);
        let lost = c.rounds.iter().filter(|r| r.emitted_at.is_none()).count();
        margin_table.push(vec![
            f2(margin),
            c.emitted().count().to_string(),
            lost.to_string(),
            pct(har_accuracy(&c)),
        ]);
    }
    margin_table.emit(out, "ablation_margin").expect("write");

    // --- Feature-order ablation (§5.1: magnitude order matters) ---
    let mut order_table = Table::new(
        "Anytime feature-order ablation (accuracy at fixed prefix)",
        &["order", "p=20", "p=40", "p=80"],
    );
    let (rows, labels) = aic::har::dataset::Corpus::features(&ctx.corpus.test);
    let ps = [20usize, 40, 80];
    let magnitude = ctx.asvm.accuracy_curve(&rows, &labels, &ps);
    let reversed = AnytimeSvm::by_reverse_magnitude(ctx.asvm.svm.clone())
        .accuracy_curve(&rows, &labels, &ps);
    order_table.push(vec![
        "by |coefficient| (paper)".into(),
        pct(magnitude[0]),
        pct(magnitude[1]),
        pct(magnitude[2]),
    ]);
    order_table.push(vec![
        "reversed (worst case)".into(),
        pct(reversed[0]),
        pct(reversed[1]),
        pct(reversed[2]),
    ]);
    order_table.emit(out, "ablation_order").expect("write");
}
