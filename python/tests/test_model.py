"""L2 pipeline tests: shapes, composition and numeric sanity."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import anytime_svm, ref


def rand(rng, *shape, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


def test_channel_features_shape_and_content():
    rng = np.random.default_rng(0)
    windows = rand(rng, 10, 6, 128)
    feats = np.asarray(model.channel_features(windows))
    assert feats.shape == (10, 6 * 9)
    assert np.isfinite(feats).all()
    # First 5 columns are channel-0 stats; check the mean column.
    np.testing.assert_allclose(
        feats[:, 0], np.asarray(windows[:, 0, :]).mean(axis=1), rtol=1e-3, atol=1e-4
    )


def test_band_energies_sum_to_one():
    rng = np.random.default_rng(1)
    x = rand(rng, 8, 128)
    dre, dim = ref.dft_matrices(128)
    power = ref.dft_power(x, dre, dim)
    bands = np.asarray(model.band_energies(power))
    assert bands.shape == (8, 4)
    np.testing.assert_allclose(bands.sum(axis=1), 1.0, atol=1e-3)
    assert (bands >= 0).all()


def test_har_pipeline_end_to_end_shape():
    rng = np.random.default_rng(2)
    b, ch, t, c = 12, 6, 128, 6
    f = ch * 9
    windows = rand(rng, b, ch, t)
    w = rand(rng, c, f)
    bias = rand(rng, c)
    mask = anytime_svm.prefix_mask(f, f // 2)
    scores = np.asarray(model.har_pipeline(windows, w, bias, mask))
    assert scores.shape == (b, c)
    assert np.isfinite(scores).all()


def test_har_pipeline_respects_mask():
    """Scores with an empty mask are the biases; with a full mask they
    match the unmasked matmul over the extracted features."""
    rng = np.random.default_rng(3)
    b, ch, t, c = 5, 6, 128, 6
    f = ch * 9
    windows = rand(rng, b, ch, t)
    w = rand(rng, c, f)
    bias = rand(rng, c)
    empty = np.asarray(
        model.har_pipeline(windows, w, bias, anytime_svm.prefix_mask(f, 0))
    )
    np.testing.assert_allclose(empty, np.tile(bias, (b, 1)), rtol=1e-5, atol=1e-5)

    full = np.asarray(
        model.har_pipeline(windows, w, bias, anytime_svm.prefix_mask(f, f))
    )
    feats = model.channel_features(windows)
    want = np.asarray(feats @ w.T + bias[None, :])
    np.testing.assert_allclose(full, want, rtol=1e-3, atol=1e-3)


def test_harris_pipeline_matches_kernel_ref():
    rng = np.random.default_rng(4)
    img = rand(rng, 40, 40, lo=0.0, hi=1.0)
    mask = jnp.ones(40, dtype=jnp.float32)
    got = model.harris_pipeline(img, mask)
    want = ref.harris_response(img, mask)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_pipeline_discriminates_activity_like_signals():
    """A dynamic (gait-like) batch and a static batch must produce
    separable features — the property the HAR classifier depends on."""
    t = 128
    n = np.arange(t)
    dynamic = np.tile(3.0 * np.sin(2 * np.pi * 5 * n / t), (4, 6, 1))
    static = np.full((4, 6, t), 0.05)
    fd = np.asarray(model.channel_features(jnp.asarray(dynamic, dtype=jnp.float32)))
    fs = np.asarray(model.channel_features(jnp.asarray(static, dtype=jnp.float32)))
    # std of channel 0 (column 1): dynamic ≫ static.
    assert fd[:, 1].min() > 10 * fs[:, 1].max()
