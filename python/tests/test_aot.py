"""AOT path tests: lowering produces parseable HLO text + manifest."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_roundtrips_through_xla_parser():
    lowered = jax.jit(model.feature_stats).lower(
        jax.ShapeDtypeStruct((8, 32), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,32]" in text
    # The lowered module must not contain a Mosaic custom-call (that would
    # mean interpret=False leaked in and the CPU PJRT client cannot run it).
    assert "tpu_custom_call" not in text


def test_entries_cover_every_pipeline():
    names = {e[0] for e in aot.entries()}
    assert names == {
        "svm_prefix",
        "svm_incremental",
        "feature_stats",
        "spectral_power",
        "har_e2e",
        "harris",
    }


def test_lower_all_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        files = set(os.listdir(d))
        assert "manifest.json" in files
        for name, meta in manifest["artifacts"].items():
            assert meta["file"] in files
            path = os.path.join(d, meta["file"])
            with open(path) as f:
                head = f.read(2000)
            assert "HloModule" in head, name
            assert meta["bytes"] > 100
        # Manifest on disk agrees with the returned one.
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest


def test_manifest_shapes_match_entry_points():
    mani = {name: args for name, _, args, _ in aot.entries()}
    assert [list(a.shape) for a in mani["svm_prefix"]] == [
        [aot.BATCH, aot.FEATURES],
        [aot.CLASSES, aot.FEATURES],
        [aot.CLASSES],
        [aot.FEATURES],
    ]
    assert [list(a.shape) for a in mani["harris"]] == [[160, 160], [160]]
