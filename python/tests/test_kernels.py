"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (batch sizes that do/don't divide the block,
feature counts, window lengths, image sizes) and value ranges;
assert_allclose against ref.py is THE correctness signal for the kernels
the AOT artifacts are built from.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import anytime_svm, features, harris, ref

SETTLE = dict(max_examples=25, deadline=None)


def farr(rng, *shape, lo=-3.0, hi=3.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


# ---------------------------------------------------------------- SVM ----


@settings(**SETTLE)
@given(
    b=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=2, max_value=160),
    c=st.integers(min_value=2, max_value=8),
    p_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_prefix_scores_matches_ref(b, n, c, p_frac, seed):
    rng = np.random.default_rng(seed)
    x = farr(rng, b, n)
    w = farr(rng, c, n)
    bias = farr(rng, c)
    p = int(round(p_frac * n))
    mask = anytime_svm.prefix_mask(n, p)
    got = anytime_svm.prefix_scores(x, w, bias, mask)
    want = ref.prefix_scores(x, w, bias, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTLE)
@given(
    b=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=64),
    c=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_incremental_update_matches_ref(b, k, c, seed):
    rng = np.random.default_rng(seed)
    s = farr(rng, b, c)
    x = farr(rng, b, k)
    w = farr(rng, c, k)
    got = anytime_svm.incremental_update(s, x, w)
    want = ref.incremental_update(s, x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_empty_prefix_gives_bias_scores():
    rng = np.random.default_rng(0)
    x = farr(rng, 7, 20)
    w = farr(rng, 3, 20)
    bias = farr(rng, 3)
    mask = anytime_svm.prefix_mask(20, 0)
    got = anytime_svm.prefix_scores(x, w, bias, mask)
    np.testing.assert_allclose(got, np.tile(bias, (7, 1)), rtol=1e-6)


def test_full_prefix_equals_plain_matmul():
    rng = np.random.default_rng(1)
    x = farr(rng, 50, 140)
    w = farr(rng, 6, 140)
    bias = farr(rng, 6)
    mask = anytime_svm.prefix_mask(140, 140)
    got = anytime_svm.prefix_scores(x, w, bias, mask)
    want = x @ w.T + bias[None, :]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_incremental_chain_equals_prefix():
    """Folding features chunk by chunk must equal the one-shot mask path
    (the anytime invariant the MCU implementation relies on)."""
    rng = np.random.default_rng(2)
    n, c, b, chunk = 64, 4, 33, 16
    x = farr(rng, b, n)
    w = farr(rng, c, n)
    bias = farr(rng, c)
    s = jnp.tile(bias[None, :], (b, 1))
    for lo in range(0, n, chunk):
        s = anytime_svm.incremental_update(
            s, x[:, lo : lo + chunk], w[:, lo : lo + chunk]
        )
    want = anytime_svm.prefix_scores(x, w, bias, anytime_svm.prefix_mask(n, n))
    np.testing.assert_allclose(s, want, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------- features ----


@settings(**SETTLE)
@given(
    b=st.integers(min_value=1, max_value=300),
    t=st.sampled_from([32, 64, 128, 100]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_window_stats_matches_ref(b, t, seed):
    rng = np.random.default_rng(seed)
    x = farr(rng, b, t, lo=-5.0, hi=5.0)
    got = features.window_stats(x)
    want = ref.window_stats(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(**SETTLE)
@given(
    b=st.integers(min_value=1, max_value=150),
    t=st.sampled_from([32, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dft_power_matches_ref_and_fft(b, t, seed):
    rng = np.random.default_rng(seed)
    x = farr(rng, b, t)
    dre, dim = ref.dft_matrices(t)
    got = features.dft_power(x, dre, dim)
    want = ref.dft_power(x, dre, dim)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # And the dense-DFT formulation itself must equal a true rfft.
    spec = np.abs(np.fft.rfft(np.asarray(x), axis=1)) ** 2 / t
    np.testing.assert_allclose(np.asarray(want), spec, rtol=1e-2, atol=1e-2)


def test_stats_of_constant_window():
    x = jnp.full((5, 64), 2.5, dtype=jnp.float32)
    out = np.asarray(features.window_stats(x))
    np.testing.assert_allclose(out[:, 0], 2.5, rtol=1e-6)  # mean
    np.testing.assert_allclose(out[:, 1], 0.0, atol=1e-3)  # std
    np.testing.assert_allclose(out[:, 2], 6.25, rtol=1e-5)  # energy
    np.testing.assert_allclose(out[:, 3], 2.5, rtol=1e-6)  # min
    np.testing.assert_allclose(out[:, 4], 2.5, rtol=1e-6)  # max


def test_pure_tone_peaks_at_its_bin():
    t, f = 128, 10
    n = np.arange(t)
    x = jnp.asarray(
        np.tile(np.sin(2 * np.pi * f * n / t), (3, 1)).astype(np.float32)
    )
    dre, dim = ref.dft_matrices(t)
    power = np.asarray(features.dft_power(x, dre, dim))
    assert np.argmax(power[0]) == f


# ------------------------------------------------------------- harris ----


@settings(**SETTLE)
@given(
    h=st.sampled_from([16, 32, 64]),
    w=st.sampled_from([16, 32, 64]),
    keep=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_harris_matches_ref(h, w, keep, seed):
    rng = np.random.default_rng(seed)
    img = farr(rng, h, w, lo=0.0, hi=1.0)
    mask = (np.arange(h) < keep * h).astype(np.float32)
    rng.shuffle(mask)
    mask = jnp.asarray(mask)
    got = harris.harris_response(img, mask)
    want = ref.harris_response(img, mask)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_harris_masked_rows_are_zero():
    rng = np.random.default_rng(3)
    img = farr(rng, 32, 32, lo=0.0, hi=1.0)
    mask = np.ones(32, dtype=np.float32)
    mask[::2] = 0.0
    out = np.asarray(harris.harris_response(img, jnp.asarray(mask)))
    assert np.all(out[::2] == 0.0)
    assert np.any(out[1::2] != 0.0)


def test_harris_flat_image_no_response():
    img = jnp.zeros((24, 24), dtype=jnp.float32)
    out = np.asarray(harris.harris_response(img, jnp.ones(24, dtype=jnp.float32)))
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


def test_harris_checkerboard_has_strong_corners():
    n, cell = 64, 8
    yy, xx = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    img = jnp.asarray((((yy // cell) + (xx // cell)) % 2).astype(np.float32))
    out = np.asarray(harris.harris_response(img, jnp.ones(n, dtype=jnp.float32)))
    # Strong positive responses at lattice crossings.
    assert out.max() > 1.0
    # Centres of cells are flat: tiny response.
    assert abs(out[cell // 2, cell // 2]) < 1e-3


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
