"""L1 Pallas kernels for window feature extraction.

Two kernels:

* `window_stats` — time-domain statistics (mean, std, energy, min, max)
  per window, blocked over the batch; pure VPU work, one VMEM pass.
* `dft_power` — the spectral features. Hardware adaptation: the MCU runs
  a radix-2 FFT, whose data-dependent butterflies are hostile to a
  systolic array; on TPU the *dense DFT matrix multiply* is both exact
  and MXU-native for the 128-sample windows the paper uses
  (DESIGN.md §Hardware-Adaptation). The [T, K] DFT matrices are
  compile-time constants living in VMEM.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls (see anytime_svm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128
NUM_STATS = 5


def _stats_kernel(x_ref, o_ref):
    x = x_ref[...]  # [BB, T]
    t = x.shape[1]
    mean = jnp.sum(x, axis=1, keepdims=True) / t
    centred = x - mean
    var = jnp.sum(centred * centred, axis=1, keepdims=True) / t
    energy = jnp.sum(x * x, axis=1, keepdims=True) / t
    mn = jnp.min(x, axis=1, keepdims=True)
    mx = jnp.max(x, axis=1, keepdims=True)
    o_ref[...] = jnp.concatenate(
        [mean, jnp.sqrt(var), energy, mn, mx], axis=1
    )


@functools.partial(jax.jit, static_argnames=())
def window_stats(x):
    """Per-window stats. x: [B, T] -> [B, 5] (mean, std, energy, min, max)."""
    bsz, t = x.shape
    padded = ((bsz + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    xp = jnp.pad(x, ((0, padded - bsz), (0, 0)))
    out = pl.pallas_call(
        _stats_kernel,
        grid=(padded // BLOCK_B,),
        in_specs=[pl.BlockSpec((BLOCK_B, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_B, NUM_STATS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, NUM_STATS), jnp.float32),
        interpret=True,
    )(xp)
    return out[:bsz]


def _dft_kernel(x_ref, re_ref, im_ref, o_ref):
    x = x_ref[...]        # [BB, T]
    dre = re_ref[...]     # [T, K]
    dim = im_ref[...]     # [T, K]
    re = jnp.dot(x, dre, preferred_element_type=jnp.float32)
    im = jnp.dot(x, dim, preferred_element_type=jnp.float32)
    o_ref[...] = (re * re + im * im) / x.shape[1]


@functools.partial(jax.jit, static_argnames=())
def dft_power(x, dft_re, dft_im):
    """Power spectrum via DFT-as-matmul. x: [B, T]; matrices [T, K] -> [B, K]."""
    bsz, t = x.shape
    k = dft_re.shape[1]
    padded = ((bsz + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    xp = jnp.pad(x, ((0, padded - bsz), (0, 0)))
    out = pl.pallas_call(
        _dft_kernel,
        grid=(padded // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, t), lambda i: (i, 0)),
            pl.BlockSpec((t, k), lambda i: (0, 0)),
            pl.BlockSpec((t, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, k), jnp.float32),
        interpret=True,
    )(xp, dft_re, dft_im)
    return out[:bsz]
