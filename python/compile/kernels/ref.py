"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references the pytest suite (and the build-time
`make artifacts` self-check) compares the kernels against. They use only
plain jax.numpy so they lower to ordinary HLO on any backend.
"""

import jax.numpy as jnp

# Harris sensitivity used by both kernel and oracle.
HARRIS_K = 0.04


def prefix_scores(x, w, b, mask):
    """OvR scores using a masked feature subset.

    x: [B, N] standardised features; w: [C, N]; b: [C];
    mask: [N] 0/1 prefix mask. Returns [B, C].
    """
    xm = x * mask[None, :]
    return xm @ w.T + b[None, :]


def incremental_update(s, x_chunk, w_chunk):
    """Anytime step: fold a feature chunk into cached scores.

    s: [B, C] partial scores; x_chunk: [B, K]; w_chunk: [C, K].
    Returns [B, C].
    """
    return s + x_chunk @ w_chunk.T


def window_stats(x):
    """Per-window statistics: mean, std, energy, min, max.

    x: [B, T]. Returns [B, 5].
    """
    mean = jnp.mean(x, axis=1)
    std = jnp.std(x, axis=1)
    energy = jnp.mean(x * x, axis=1)
    mn = jnp.min(x, axis=1)
    mx = jnp.max(x, axis=1)
    return jnp.stack([mean, std, energy, mn, mx], axis=1)


def dft_matrices(t, dtype=jnp.float32):
    """Dense DFT matrices for the rfft bins 0..T/2.

    Returns (real [T, T//2+1], imag [T, T//2+1]) such that
    X @ real, X @ imag give the real/imaginary spectrum parts.
    """
    k = jnp.arange(t // 2 + 1, dtype=dtype)
    n = jnp.arange(t, dtype=dtype)
    ang = -2.0 * jnp.pi * n[:, None] * k[None, :] / t
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def dft_power(x, dft_re, dft_im):
    """Power spectrum via DFT-as-matmul (the MXU formulation).

    x: [B, T]; dft_re/dft_im: [T, K]. Returns [B, K] with |X_k|^2 / T.
    """
    re = x @ dft_re
    im = x @ dft_im
    return (re * re + im * im) / x.shape[1]


def harris_response(img, row_mask, k=HARRIS_K):
    """Harris response with row perforation.

    img: [H, W] grayscale; row_mask: [H] 0/1 (perforated rows output 0).
    Border-replicated Sobel gradients, 3x3 structure tensor, R = det - k tr^2.
    Returns [H, W].
    """

    def shift(a, dy, dx):
        # Border replication via edge padding then slicing.
        p = jnp.pad(a, ((1, 1), (1, 1)), mode="edge")
        h, w = a.shape
        return p[1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]

    ix = (
        shift(img, -1, 1) + 2.0 * shift(img, 0, 1) + shift(img, 1, 1)
        - shift(img, -1, -1) - 2.0 * shift(img, 0, -1) - shift(img, 1, -1)
    )
    iy = (
        shift(img, 1, -1) + 2.0 * shift(img, 1, 0) + shift(img, 1, 1)
        - shift(img, -1, -1) - 2.0 * shift(img, -1, 0) - shift(img, -1, 1)
    )
    ixx, ixy, iyy = ix * ix, ix * iy, iy * iy

    def window_sum(a):
        total = jnp.zeros_like(a)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                total = total + shift(a, dy, dx)
        return total

    sxx = window_sum(ixx)
    sxy = window_sum(ixy)
    syy = window_sum(iyy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    r = det - k * tr * tr
    return r * row_mask[:, None]
