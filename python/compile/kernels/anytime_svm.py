"""L1 Pallas kernels for anytime OvR-SVM scoring.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot loop
is a fixed-point MAC chain on a 16-bit MCU. On TPU the same computation —
scores over a feature *prefix* — becomes an MXU matmul over the batch of
windows the emulation experiments replay: `S = (X ⊙ mask) @ Wᵀ + b`. The
prefix knob is a VMEM-resident 0/1 mask so every prefix length shares one
compiled executable. The incremental (anytime) refinement step is a thin
matmul over a feature chunk, accumulated into the cached scores exactly
like the MCU's cached partial sums (§3.2).

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness — not CPU wallclock — is what the
interpret path validates (see DESIGN.md §Perf for the VMEM/MXU analysis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: one VMEM-resident block of windows. 128 rows matches the
# MXU systolic dimension; N=140 features and C=6 classes easily co-reside.
BLOCK_B = 128


def _prefix_scores_kernel(x_ref, w_ref, b_ref, mask_ref, o_ref):
    """One batch block: o = (x * mask) @ w^T + b."""
    x = x_ref[...]            # [BB, N]
    mask = mask_ref[...]      # [1, N]
    w = w_ref[...]            # [C, N]
    b = b_ref[...]            # [1, C]
    xm = x * mask             # masked prefix, VPU elementwise
    # MXU contraction over features.
    o_ref[...] = jnp.dot(xm, w.T, preferred_element_type=jnp.float32) + b


@functools.partial(jax.jit, static_argnames=())
def prefix_scores(x, w, b, mask):
    """Masked OvR scores. x: [B, N]; w: [C, N]; b: [C]; mask: [N] -> [B, C].

    B is padded to a multiple of BLOCK_B; the pad is sliced off again, so
    callers may pass any batch size.
    """
    bsz, n = x.shape
    c = w.shape[0]
    padded = ((bsz + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    xp = jnp.pad(x, ((0, padded - bsz), (0, 0)))
    grid = (padded // BLOCK_B,)
    out = pl.pallas_call(
        _prefix_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, n), lambda i: (i, 0)),
            pl.BlockSpec((c, n), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, c), jnp.float32),
        interpret=True,
    )(xp, w, b.reshape(1, c), mask.reshape(1, n))
    return out[:bsz]


def _incremental_kernel(s_ref, x_ref, w_ref, o_ref):
    """One batch block of the anytime step: o = s + x_chunk @ w_chunk^T."""
    s = s_ref[...]   # [BB, C]
    x = x_ref[...]   # [BB, K]
    w = w_ref[...]   # [C, K]
    o_ref[...] = s + jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def incremental_update(s, x_chunk, w_chunk):
    """Anytime refinement: fold a feature chunk into cached scores.

    s: [B, C]; x_chunk: [B, K]; w_chunk: [C, K] -> [B, C].
    """
    bsz, c = s.shape
    k = x_chunk.shape[1]
    padded = ((bsz + BLOCK_B - 1) // BLOCK_B) * BLOCK_B
    sp = jnp.pad(s, ((0, padded - bsz), (0, 0)))
    xp = jnp.pad(x_chunk, ((0, padded - bsz), (0, 0)))
    grid = (padded // BLOCK_B,)
    out = pl.pallas_call(
        _incremental_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, c), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),
            pl.BlockSpec((c, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, c), jnp.float32),
        interpret=True,
    )(sp, xp, w_chunk)
    return out[:bsz]


def prefix_mask(n, p, dtype=jnp.float32):
    """The 0/1 mask selecting the first p entries of an n-feature order."""
    return (jnp.arange(n) < p).astype(dtype)
