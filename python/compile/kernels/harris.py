"""L1 Pallas kernel for the perforated Harris response.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper perforates
an MCU loop by *skipping iterations*; data-dependent control flow is
foreign to a systolic/vector unit, so the same knob — the fraction of rows
not computed — becomes a multiplicative 0/1 row mask fused into a dense
response computation. The image (160×160 f32 ≈ 100 KiB) plus its gradient
products fit comfortably in one VMEM tile, so the kernel runs as a single
grid cell; skipped rows are zeroed by the mask, exactly matching the
engine's row-perforation semantics where uncomputed rows hold no response.

interpret=True: CPU PJRT cannot run Mosaic custom-calls (see
anytime_svm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import HARRIS_K


def _harris_kernel(img_ref, mask_ref, o_ref):
    img = img_ref[...]      # [H, W]
    mask = mask_ref[...]    # [1, H]

    def shift(a, dy, dx):
        # Border replication: roll + edge fixups are awkward in VMEM;
        # slicing a padded copy is one VPU pass.
        p = jnp.pad(a, ((1, 1), (1, 1)), mode="edge")
        h, w = a.shape
        return jax.lax.dynamic_slice(p, (1 + dy, 1 + dx), (h, w))

    ix = (
        shift(img, -1, 1) + 2.0 * shift(img, 0, 1) + shift(img, 1, 1)
        - shift(img, -1, -1) - 2.0 * shift(img, 0, -1) - shift(img, 1, -1)
    )
    iy = (
        shift(img, 1, -1) + 2.0 * shift(img, 1, 0) + shift(img, 1, 1)
        - shift(img, -1, -1) - 2.0 * shift(img, -1, 0) - shift(img, -1, 1)
    )
    ixx, ixy, iyy = ix * ix, ix * iy, iy * iy

    def wsum(a):
        total = jnp.zeros_like(a)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                total = total + shift(a, dy, dx)
        return total

    sxx, sxy, syy = wsum(ixx), wsum(ixy), wsum(iyy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    r = det - HARRIS_K * tr * tr
    o_ref[...] = r * mask.T  # [H, 1] broadcast over columns


@functools.partial(jax.jit, static_argnames=())
def harris_response(img, row_mask):
    """Perforated Harris response. img: [H, W]; row_mask: [H] -> [H, W]."""
    h, w = img.shape
    return pl.pallas_call(
        _harris_kernel,
        in_specs=[
            pl.BlockSpec((h, w), lambda: (0, 0)),
            pl.BlockSpec((1, h), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((h, w), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(img, row_mask.reshape(1, h))
