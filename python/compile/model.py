"""L2: the JAX compute pipelines composed from the L1 Pallas kernels.

Python exists only on the compile path: these functions are lowered once
by aot.py into HLO-text artifacts that the Rust coordinator loads through
PJRT for accelerated batch replay (runtime/ in the Rust tree). Weights,
masks and inputs are all runtime *parameters* of the executables, so a
single artifact serves every model the Rust side trains.
"""

import jax.numpy as jnp

from compile.kernels import anytime_svm, features, harris
from compile.kernels.ref import dft_matrices

# Spectral band boundaries in rfft bins for T=128 (matches the Rust
# feature catalog: ~0.4-1.6, 1.6-3.1, 3.1-6.2, 6.2-25 Hz at 50 Hz).
BAND_EDGES = (1, 4, 8, 16, 65)
NUM_BANDS = len(BAND_EDGES) - 1


def band_energies(power):
    """Normalised band energies from a power spectrum [B, K] -> [B, 4]."""
    total = jnp.sum(power[:, 1:], axis=1, keepdims=True) + 1e-12
    bands = [
        jnp.sum(power[:, BAND_EDGES[i] : BAND_EDGES[i + 1]], axis=1, keepdims=True)
        for i in range(NUM_BANDS)
    ]
    return jnp.concatenate(bands, axis=1) / total


def channel_features(windows):
    """Feature block for a batch of multi-channel windows.

    windows: [B, CH, T] -> [B, CH * (5 + 4)] — five time statistics plus
    four spectral band energies per channel, kernels doing the heavy math.
    """
    b, ch, t = windows.shape
    dre, dim = dft_matrices(t)
    blocks = []
    for c in range(ch):
        x = windows[:, c, :]
        blocks.append(features.window_stats(x))
        blocks.append(band_energies(features.dft_power(x, dre, dim)))
    return jnp.concatenate(blocks, axis=1)


def har_pipeline(windows, w, bias, mask):
    """End-to-end HAR compute graph: windows -> features -> masked scores.

    windows: [B, CH, T]; w: [C, F]; bias: [C]; mask: [F] -> scores [B, C].
    F must equal CH * 9.
    """
    feats = channel_features(windows)
    return anytime_svm.prefix_scores(feats, w, bias, mask)


def svm_prefix(x, w, bias, mask):
    """Bare prefix-scoring entry point (features precomputed on-device)."""
    return anytime_svm.prefix_scores(x, w, bias, mask)


def svm_incremental(s, x_chunk, w_chunk):
    """Bare anytime-step entry point."""
    return anytime_svm.incremental_update(s, x_chunk, w_chunk)


def feature_stats(x):
    """Bare window-statistics entry point."""
    return features.window_stats(x)


def spectral_power(x):
    """Power spectrum of a batch of windows (DFT matrices baked in)."""
    dre, dim = dft_matrices(x.shape[1])
    return features.dft_power(x, dre, dim)


def harris_pipeline(img, row_mask):
    """Perforated Harris response entry point."""
    return harris.harris_response(img, row_mask)
