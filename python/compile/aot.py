"""AOT lowering: JAX pipelines -> HLO-text artifacts + manifest.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as `python -m compile.aot --out-dir ../artifacts` (what
`make artifacts` does). Python never runs again after this: the Rust
binary loads the artifacts through PJRT.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Fixed artifact shapes (recorded in the manifest; the Rust runtime
# asserts against them). Batch sizes are the replay-batch granularity.
BATCH = 256
CHANNELS = 6
WINDOW = 128
FEATURES = 140
CLASSES = 6
CHUNK = 16
IMG = 160
E2E_FEATURES = CHANNELS * 9  # channel_features output width


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entries():
    """(name, fn, example_args, description) for every artifact."""
    return [
        (
            "svm_prefix",
            model.svm_prefix,
            (f32(BATCH, FEATURES), f32(CLASSES, FEATURES), f32(CLASSES), f32(FEATURES)),
            "masked OvR scores: (x, w, b, mask) -> [B, C]",
        ),
        (
            "svm_incremental",
            model.svm_incremental,
            (f32(BATCH, CLASSES), f32(BATCH, CHUNK), f32(CLASSES, CHUNK)),
            "anytime step: (s, x_chunk, w_chunk) -> [B, C]",
        ),
        (
            "feature_stats",
            model.feature_stats,
            (f32(BATCH, WINDOW),),
            "window stats: x -> [B, 5] (mean, std, energy, min, max)",
        ),
        (
            "spectral_power",
            model.spectral_power,
            (f32(BATCH, WINDOW),),
            "DFT-as-matmul power spectrum: x -> [B, T/2+1]",
        ),
        (
            "har_e2e",
            model.har_pipeline,
            (
                f32(BATCH, CHANNELS, WINDOW),
                f32(CLASSES, E2E_FEATURES),
                f32(CLASSES),
                f32(E2E_FEATURES),
            ),
            "windows -> channel features -> masked scores [B, C]",
        ),
        (
            "harris",
            model.harris_pipeline,
            (f32(IMG, IMG), f32(IMG)),
            "perforated Harris response: (img, row_mask) -> [H, W]",
        ),
    ]


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": {}}
    for name, fn, args, desc in entries():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "description": desc,
            "inputs": [list(a.shape) for a in args],
            "bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    lower_all(args.out_dir)


if __name__ == "__main__":
    main()
